"""Paper Table 2: resource usage. The FPGA budget (DSP/LUT/FF/BRAM) maps to
the TPU kernel's VMEM working set per core (16 MiB v5e). Reported for the
same four configurations the paper synthesizes: Longformer FP16(bf16),
BigBird, dual-pipeline BigBird, and FP32."""
from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels.ops import get_pattern
from benchmarks.common import emit

VMEM = 16 * 2 ** 20


def working_set(block_q, block_kv, head_dim, dtype_bytes, pipelines=1):
    q = block_q * head_dim * dtype_bytes
    kv = 2 * block_kv * head_dim * dtype_bytes * 2   # double-buffered DMA
    acc = block_q * head_dim * 4                     # fp32 accumulator
    stats = 2 * block_q * 128 * 4                    # m, l scratch
    out = block_q * head_dim * dtype_bytes
    return (q + kv + acc + stats + out) * pipelines


def main():
    configs = [
        ("longformer_bf16", 128, 128, 64, 2, 1),
        ("bigbird_bf16", 128, 128, 64, 2, 1),
        ("bigbird_bf16_x2", 128, 128, 64, 2, 2),
        ("longformer_fp32", 128, 128, 64, 4, 1),
    ]
    for name, bq, bk, h, db, pipes in configs:
        ws = working_set(bq, bk, h, db, pipes)
        emit(f"table2/vmem_{name}", 0.0,
             f"{ws / 1024:.0f}KiB={ws / VMEM * 100:.1f}%_of_VMEM")
    # slot counts (grid width) for the two paper patterns at 4096 tokens
    lf = get_pattern(AttentionSpec(kind="swat", window=256, num_global=1,
                                   causal=False), 4096, 4096, 128, 128)
    bb = get_pattern(AttentionSpec(kind="swat", window=96, num_global=128,
                                   num_random=2, causal=False,
                                   random_seed=2024), 4096, 4096, 128, 128)
    emit("table2/slots_longformer", 0.0, f"{lf.num_slots}")
    emit("table2/slots_bigbird", 0.0, f"{bb.num_slots}")
    emit("table2/active_frac_longformer", 0.0, f"{lf.active_fraction():.4f}")
    emit("table2/active_frac_bigbird", 0.0, f"{bb.active_fraction():.4f}")


if __name__ == "__main__":
    main()
