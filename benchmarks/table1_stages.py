"""Paper Table 1: pipeline stage timing balance. The FPGA pipeline
(LOAD/QK/SV/ZRED/ROWSUM/DIV at ~201 cycles) maps on TPU to the per-grid-step
phases of the fused kernel. We report each phase's FLOPs / bytes and its
v5e cycle estimate (MXU 128x128 bf16, VPU 8x128 lanes) for the paper's
standard config (H=64, 2w=512, BQ=BK=128) — the structural analogue of the
stage-balance table: no phase should dominate end-to-end.
"""
from benchmarks.common import emit

H = 64          # head dim (paper's H)
BQ = BK = 128   # MXU-aligned blocks
MXU_FLOPS_PER_CYCLE = 128 * 128 * 2
VPU_LANES = 8 * 128
CLOCK = 940e6   # v5e ~0.94 GHz


def cycles_mxu(flops):
    return flops / MXU_FLOPS_PER_CYCLE


def cycles_vpu(elems, ops_per_elem=1):
    return elems * ops_per_elem / VPU_LANES


def main():
    # per grid step: one (BQ x H) q block vs one (BK x H) kv block
    qk = 2 * BQ * BK * H                 # S = Q K^T
    exp = BQ * BK                        # exp (VPU, ~7 ops)
    sv = 2 * BQ * BK * H                 # S' V accumulate
    red = BQ * H                         # running rescale acc
    rowsum = BQ * BK                     # l update
    div = BQ * H                         # final divide (amortized / slots)

    load_bytes = (BK * H * 2) * 2        # K + V blocks bf16 (the paper's LOAD)
    load_cycles = load_bytes / (819e9 / CLOCK)

    stages = [
        ("LOAD(K/V DMA)", load_cycles),
        ("QK (MXU)", cycles_mxu(qk)),
        ("EXP (VPU)", cycles_vpu(exp, 7)),
        ("SV (MXU)", cycles_mxu(sv)),
        ("ZRED/rescale (VPU)", cycles_vpu(red, 4)),
        ("ROWSUM (VPU)", cycles_vpu(rowsum, 1)),
        ("DIV&OUT (VPU)", cycles_vpu(div, 3)),
    ]
    total = max(c for _, c in stages)    # pipelined: bound by slowest stage
    for name, c in stages:
        emit(f"table1/{name}", c / CLOCK * 1e6, f"{c:.0f}_cycles")
    emit("table1/pipeline_bound", total / CLOCK * 1e6,
         f"{total:.0f}_cycles_per_block_step")
    # paper's FPGA pipeline: 201 cycles per row of ONE attention core;
    # TPU block step covers 128x128 rows x cols at once.
    rows_per_step = BQ
    emit("table1/rows_per_cycle_vs_fpga", 0.0,
         f"tpu={rows_per_step / total:.2f}_fpga={1 / 201:.4f}")


if __name__ == "__main__":
    main()
