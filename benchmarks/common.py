"""Shared benchmark utilities: timing + CSV row emission."""
import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
