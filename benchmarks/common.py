"""Shared benchmark utilities: timing + CSV row emission + JSON artifacts."""
import json
import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, payload: dict):
    """Machine-readable benchmark artifact (BENCH_*.json): future PRs diff
    these files to track the perf trajectory instead of re-deriving numbers
    from prose. Adds backend/device metadata so deltas across environments
    are never silently compared."""
    payload = dict(payload)
    payload.setdefault("backend", jax.default_backend())
    payload.setdefault("device_count", jax.device_count())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench] wrote {path}")
