"""Paper Fig. 1: FLOPs and memory-operation breakdown of a transformer layer
by input length — attention grows quadratically (dense) vs linearly (SWAT).

Analytic counts from the exact layer shapes (the same math the roofline
uses), reported per input length for dense / sliding-chunks / SWAT.
"""
from repro.core.types import AttentionSpec
from benchmarks.common import emit

D_MODEL, HEADS, D_FF, HEAD_DIM = 768, 12, 3072, 64
W = 256  # 2w = 512, the paper's standard config


def layer_flops(seq: int, spec: AttentionSpec):
    qkv = 2 * seq * D_MODEL * 3 * HEADS * HEAD_DIM
    attn = seq * HEADS * spec.flops_per_row(seq, HEAD_DIM)
    proj = 2 * seq * HEADS * HEAD_DIM * D_MODEL
    ffn = 2 * seq * D_MODEL * D_FF * 3
    return qkv + proj + ffn, attn


def layer_mops(seq: int, spec: AttentionSpec):
    """bf16 bytes moved if S/S' spill off-chip (the un-fused baseline) vs
    fused (S never leaves on-chip memory — the paper's kernel fusion)."""
    cols = (seq if spec.kind == "dense"
            else min(seq, 2 * spec.window + 1))
    s_bytes = 2 * seq * HEADS * cols * 2 * 2   # S and S', write+read
    x_bytes = seq * D_MODEL * 2 * 8
    return x_bytes, s_bytes


def main():
    dense = AttentionSpec(kind="dense", causal=False)
    swat = AttentionSpec(kind="swat", window=W, causal=False)
    chunks = AttentionSpec(kind="sliding_chunks", window=W, causal=False)
    for seq in (1024, 4096, 16384, 65536):
        base, a_dense = layer_flops(seq, dense)
        _, a_swat = layer_flops(seq, swat)
        _, a_chunks = layer_flops(seq, chunks)
        x_b, s_b = layer_mops(seq, dense)
        _, s_b_swat = layer_mops(seq, swat)
        emit(f"fig1/flops_frac_attn_dense/seq{seq}", 0.0,
             f"{a_dense / (a_dense + base):.3f}")
        emit(f"fig1/flops_frac_attn_swat/seq{seq}", 0.0,
             f"{a_swat / (a_swat + base):.3f}")
        emit(f"fig1/flops_ratio_chunks_vs_swat/seq{seq}", 0.0,
             f"{a_chunks / a_swat:.2f}")
        emit(f"fig1/mops_unfused_S_vs_x_dense/seq{seq}", 0.0,
             f"{s_b / x_b:.2f}")
        emit(f"fig1/mops_unfused_S_vs_x_swat/seq{seq}", 0.0,
             f"{s_b_swat / x_b:.2f}")


if __name__ == "__main__":
    main()
