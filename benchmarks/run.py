"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.emit).
"""
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.fig1_flops_mops",     # Fig. 1 FLOPs/MOPs breakdown
    "benchmarks.fig2_redundancy",     # Fig. 2 / §1 chunk redundancy
    "benchmarks.fig3_scaling",        # Fig. 3 time+memory scaling
    "benchmarks.table1_stages",       # Table 1 pipeline balance
    "benchmarks.table2_vmem",         # Table 2 resource usage
    "benchmarks.fig8_speedup",        # Figs. 8-9 speedup/energy
    "benchmarks.kernel_bench",        # kernel microbenches
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        try:
            importlib.import_module(mod_name).main()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
