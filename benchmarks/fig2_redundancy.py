"""Paper Fig. 2 / §1: sliding-chunks wastes 1/2 - 1/(4|chunks|) of its
FLOPs on overlap+corner regions; SWAT's exact-band wastes only block-edge
padding. Measured from the actual block patterns / chunk schedules."""
import numpy as np

from repro.core import patterns
from repro.core.types import AttentionSpec
from benchmarks.common import emit


def exact_band_elements(seq, w, causal=False):
    m = patterns.dense_mask(
        AttentionSpec(kind="swat", window=w, causal=causal), seq, seq)
    return int(m.sum())


def main():
    w = 256
    for seq in (1024, 4096, 16384):
        useful = exact_band_elements(seq, w)
        # sliding chunks computes |chunks| dense (2w x 3*2w) products
        # (2 neighbours + self), bidirectional
        c = 2 * w
        n_chunks = seq // c
        chunk_elems = n_chunks * c * (3 * c) - 2 * c * c  # ends have 2 chunks
        formula = patterns.sliding_chunks_flops_ratio(seq, w)
        measured = 1.0 - useful / chunk_elems
        emit(f"fig2/chunks_redundancy_measured/seq{seq}", 0.0,
             f"{measured:.3f}")
        emit(f"fig2/chunks_redundancy_formula/seq{seq}", 0.0,
             f"{formula:.3f}")
        # SWAT block-edge waste at block 128
        pat = patterns.build_block_pattern(
            AttentionSpec(kind="swat", window=w, causal=False),
            seq, seq, 128, 128)
        visited = int((pat.slot_kinds != patterns.PAD).sum()) * 128 * 128
        emit(f"fig2/swat_block_edge_waste/seq{seq}", 0.0,
             f"{1.0 - useful / visited:.3f}")


if __name__ == "__main__":
    main()
