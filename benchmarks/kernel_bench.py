"""Microbenchmarks of the three SWAT Pallas kernels (interpret mode on CPU —
correct-path exercise + relative block-shape comparisons; real speed is a
TPU property) and their XLA twins (compiled)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AttentionSpec
from repro.kernels.ops import swat_attention
from repro.kernels.swat_decode import swat_decode
from benchmarks.common import emit, time_fn


def main():
    rng = np.random.RandomState(0)
    spec = AttentionSpec(kind="swat", window=128, causal=True)
    b, hq, hkv, l, d = 1, 4, 2, 1024, 64
    q = jnp.asarray(rng.randn(b, hq, l, d), jnp.bfloat16)
    k = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)
    v = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)

    for bq in (64, 128, 256):
        fn = jax.jit(lambda q, k, v: swat_attention(
            q, k, v, spec, block_q=bq, block_kv=bq, impl="xla"))
        t = time_fn(fn, q, k, v, iters=3, warmup=1)
        emit(f"kernel/xla_banded_block{bq}", t, f"seq{l}")

    # decode kernel (ring cache) vs cache size
    for w in (512, 2048, 8192):
        kc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        vc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        qd = jnp.asarray(rng.randn(8, hq, 1, d), jnp.bfloat16)
        cl = jnp.full((8,), w, jnp.int32)
        fn = jax.jit(lambda q, k, v, c: swat_decode(q, k, v, c,
                                                    interpret=True))
        t = time_fn(fn, qd, kc, vc, cl, iters=2, warmup=1)
        emit(f"kernel/decode_ring_w{w}", t, "interpret")


if __name__ == "__main__":
    main()
