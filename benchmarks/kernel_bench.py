"""Microbenchmarks of the three SWAT Pallas kernels (interpret mode on CPU —
correct-path exercise + relative block-shape comparisons; real speed is a
TPU property) and their XLA twins (compiled)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import _round_capacity
from repro.core.types import AttentionSpec
from repro.kernels.ops import swat_attention
from repro.kernels.swat_decode import decode_block_kv, swat_decode
from benchmarks.common import emit, time_fn


def main():
    rng = np.random.RandomState(0)
    spec = AttentionSpec(kind="swat", window=128, causal=True)
    b, hq, hkv, l, d = 1, 4, 2, 1024, 64
    q = jnp.asarray(rng.randn(b, hq, l, d), jnp.bfloat16)
    k = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)
    v = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)

    for bq in (64, 128, 256):
        fn = jax.jit(lambda q, k, v: swat_attention(
            q, k, v, spec, block_q=bq, block_kv=bq, impl="xla"))
        t = time_fn(fn, q, k, v, iters=3, warmup=1)
        emit(f"kernel/xla_banded_block{bq}", t, f"seq{l}")

    # decode kernel (ring cache) vs cache size
    for w in (512, 2048, 8192):
        kc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        vc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        qd = jnp.asarray(rng.randn(8, hq, 1, d), jnp.bfloat16)
        cl = jnp.full((8,), w, jnp.int32)
        fn = jax.jit(lambda q, k, v, c: swat_decode(q, k, v, c,
                                                    interpret=True))
        t = time_fn(fn, qd, kc, vc, cl, iters=2, warmup=1)
        emit(f"kernel/decode_ring_w{w}", t, "interpret")

    # decode repad before/after: a window+1+globals capacity that is not a
    # block multiple used to jnp.pad (COPY) both caches on EVERY decode
    # call; init_kv_cache capacities are now pre-rounded so the hot path
    # tiles exactly. `before` = the legacy unrounded capacity (falls back
    # to pad); `after` = the rounded capacity init_kv_cache actually
    # allocates (must take the no-pad path). 2001 rounds to 2048, so both
    # sides run the SAME 128-wide grid and the delta isolates the per-call
    # pad copy (2 * B * Hkv * cap * D bf16 bytes per layer per token).
    cap_raw = 1996 + 1 + 4                      # window + 1 + num_global
    cap = _round_capacity(cap_raw)
    blk, pads = decode_block_kv(cap)
    # ring (sparse-spec) caches from init_kv_cache never pad; dense caps
    # follow max_len verbatim and may still hit the fallback for odd values
    assert not pads, (cap, blk)
    assert cap % blk == 0 and blk == 128, (cap, blk)
    assert decode_block_kv(cap_raw)[1], cap_raw  # legacy width DID pad
    copied = 2 * 8 * hkv * cap_raw * d * 2
    emit("kernel/decode_repad_bytes_per_call", float(copied), "eliminated")
    for label, w in (("pad_before", cap_raw), ("nopad_after", cap)):
        kc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        vc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        qd = jnp.asarray(rng.randn(8, hq, 1, d), jnp.bfloat16)
        cl = jnp.full((8,), w, jnp.int32)
        fn = jax.jit(lambda q, k, v, c: swat_decode(q, k, v, c,
                                                    interpret=True))
        t = time_fn(fn, qd, kc, vc, cl, iters=2, warmup=1)
        emit(f"kernel/decode_repad_{label}_w{w}", t, "interpret")


if __name__ == "__main__":
    main()
