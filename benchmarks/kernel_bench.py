"""Microbenchmarks of the SWAT Pallas kernels (interpret mode on CPU —
correct-path exercise + relative comparisons; real speed is a TPU property)
and their XLA twins (compiled).

The headline section times the decode hot path before/after the flash-decode
rework at production GQA shapes:

  before = the PR-2 path: a separate ring-scatter dispatch per call
           (layers._dyn_update) followed by the per-(batch, q-head) kernel —
           grid (B, Hq, nb), a (1, D) query row per program (~1/128 MXU tile)
  after  = the fused kernel: ring insert inside the attention pass
           (input/output aliasing) with the group = Hq/Hkv query heads
           packed into one (group*T, D) tile — grid (B, Hkv, nb)

On the interpret backend the measured ratio is dominated by program count
(grid steps) and per-step work — a proxy for the MXU-utilization win, not a
TPU number; the BENCH_kernel.json artifact records backend + shapes so
future PRs compare like with like.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--out BENCH_kernel.json]
    PYTHONPATH=src python benchmarks/kernel_bench.py --smoke   # CI fast lane

--smoke skips all timing and instead asserts the kernel-shape invariants
that silently regress otherwise: engine ring allocations tile exactly (no
pad-and-copy fallback), the fused kernel's insert+attend matches the jnp
oracle (cache updates bitwise), and packed/unpacked layouts agree.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))  # `python benchmarks/kernel_bench.py` from anywhere

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import _dyn_update, _round_capacity, cache_allocation
from repro.core.types import AttentionSpec
from repro.kernels import ref
from repro.kernels.ops import decode_attention, swat_attention
from repro.kernels.swat_decode import decode_block_kv, swat_decode
from benchmarks.common import emit, time_fn, write_json

# (B, group, W): the ISSUE-3 production sweep. W=4096 runs only at the
# acceptance shape (grid cost in interpret mode scales with B*Hq*nb; the
# relative before/after story is identical at every W).
GQA_SWEEP = [(8, 1, 512), (8, 4, 512), (8, 8, 512),
             (32, 1, 512), (32, 4, 512), (32, 8, 512),
             (8, 8, 4096), (32, 8, 4096)]
ACCEPT_SHAPE = (32, 8, 4096)
HKV, D = 2, 64

# Known fused-vs-PR2 loss shapes, TRACKED not silent: at group<=4 and small
# W the packed tile is mostly padding, so the fused kernel loses to the
# scatter+per-head path (0.34-0.86x on the interpret backend). The
# shape-adaptive dispatch item on the ROADMAP exists to reclaim these; any
# OTHER shape dropping below 1.0x — or these getting materially worse —
# must fail the smoke gate, not scroll by.
EXPECTED_REGRESSIONS = {(8, 1, 512), (32, 1, 512), (32, 4, 512)}


def _decode_args(rng, b, group, w, t=1, dtype=jnp.bfloat16):
    hq = group * HKV
    q = jnp.asarray(rng.randn(b, hq, t, D), dtype)
    kc = jnp.asarray(rng.randn(b, HKV, w, D), dtype)
    vc = jnp.asarray(rng.randn(b, HKV, w, D), dtype)
    nk = jnp.asarray(rng.randn(b, HKV, t, D), dtype)
    nv = jnp.asarray(rng.randn(b, HKV, t, D), dtype)
    step = jnp.full((b,), w + 7, jnp.int32)      # wrapped ring, fully valid
    return q, kc, vc, nk, nv, step


def bench_decode_gqa(rng, shapes, iters):
    """before (scatter + per-head kernel) vs after (fused + GQA-packed)."""
    rows = []
    for b, group, w in shapes:
        q, kc, vc, nk, nv, step = _decode_args(rng, b, group, w)

        def before(q, kc, vc, nk, nv, step):
            # PR-2 decode: ring scatter pass (full-cache HBM round trip)
            # then the unpacked (1, D)-row kernel over grid (B, Hq, nb)
            slot = step % w
            kci = _dyn_update(kc, nk, slot)
            vci = _dyn_update(vc, nv, slot)
            cl = jnp.minimum(step + 1, w)
            o = swat_decode(q, kci, vci, cl, pack_gqa=False, interpret=True)
            return o, kci, vci

        def after(q, kc, vc, nk, nv, step):
            return swat_decode(q, kc, vc, step, new_k=nk, new_v=nv,
                               interpret=True)

        it = 1 if w >= 4096 else iters   # W=4096 interpret runs are minutes
        t_b = time_fn(jax.jit(before), q, kc, vc, nk, nv, step,
                      iters=it, warmup=1)
        t_a = time_fn(jax.jit(after), q, kc, vc, nk, nv, step,
                      iters=it, warmup=1)
        speedup = t_b / t_a
        emit(f"kernel/decode_gqa_b{b}_g{group}_w{w}_before", t_b, "interpret")
        emit(f"kernel/decode_gqa_b{b}_g{group}_w{w}_after", t_a,
             f"speedup {speedup:.2f}x")
        rows.append({"b": b, "group": group, "hkv": HKV, "w": w, "d": D,
                     "t": 1, "us_before": t_b, "us_after": t_a,
                     "speedup": round(speedup, 3)})
    return rows


def bench_multi_token(rng, iters):
    """T=4 fused step vs 4 sequential fused T=1 steps: the multi-query tile
    amortizes the full-cache read T times — the speculative-verify win."""
    b, group, w, t = 8, 4, 512, 4
    q, kc, vc, nk, nv, step = _decode_args(rng, b, group, w, t=t)
    cap = w  # dense-style modulus; relative timing only

    def one_shot(q, kc, vc, nk, nv, step):
        return swat_decode(q, kc, vc, step, new_k=nk, new_v=nv,
                           ring_cap=cap, interpret=True)

    def sequential(q, kc, vc, nk, nv, step):
        outs = []
        for j in range(t):
            o, kc, vc = swat_decode(q[:, :, j:j + 1], kc, vc, step + j,
                                    new_k=nk[:, :, j:j + 1],
                                    new_v=nv[:, :, j:j + 1],
                                    ring_cap=cap, interpret=True)
            outs.append(o)
        return jnp.concatenate(outs, 2), kc, vc

    t_seq = time_fn(jax.jit(sequential), q, kc, vc, nk, nv, step,
                    iters=iters, warmup=1)
    t_one = time_fn(jax.jit(one_shot), q, kc, vc, nk, nv, step,
                    iters=iters, warmup=1)
    emit(f"kernel/decode_multitoken_t{t}_sequential", t_seq, "interpret")
    emit(f"kernel/decode_multitoken_t{t}_fused", t_one,
         f"speedup {t_seq / t_one:.2f}x")
    return {"b": b, "group": group, "w": w, "t": t, "us_sequential": t_seq,
            "us_fused": t_one, "speedup": round(t_seq / t_one, 3)}


def bench_xla_banded(rng, iters):
    spec = AttentionSpec(kind="swat", window=128, causal=True)
    b, hq, hkv, l, d = 1, 4, 2, 1024, 64
    q = jnp.asarray(rng.randn(b, hq, l, d), jnp.bfloat16)
    k = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)
    v = jnp.asarray(rng.randn(b, hkv, l, d), jnp.bfloat16)
    rows = []
    for bq in (64, 128, 256):
        fn = jax.jit(lambda q, k, v: swat_attention(
            q, k, v, spec, block_q=bq, block_kv=bq, impl="xla"))
        t = time_fn(fn, q, k, v, iters=iters, warmup=1)
        emit(f"kernel/xla_banded_block{bq}", t, f"seq{l}")
        rows.append({"block": bq, "seq": l, "us": t})
    return rows


def bench_repad(rng, iters):
    """Decode repad before/after: a window+1+globals capacity that is not a
    block multiple used to jnp.pad (COPY) both caches on EVERY decode call;
    init_kv_cache capacities are pre-rounded so the hot path tiles exactly.
    2001 rounds to 2048, so both sides run the SAME 128-wide grid and the
    delta isolates the per-call pad copy."""
    hkv, d = 2, 64
    cap_raw = 1996 + 1 + 4                      # window + 1 + num_global
    cap = _round_capacity(cap_raw)
    blk, pads = decode_block_kv(cap)
    assert not pads and cap % blk == 0 and blk == 128, (cap, blk)
    assert decode_block_kv(cap_raw)[1], cap_raw  # legacy width DID pad
    copied = 2 * 8 * hkv * cap_raw * d * 2
    emit("kernel/decode_repad_bytes_per_call", float(copied), "eliminated")
    out = {"bytes_per_call_eliminated": copied}
    for label, w in (("pad_before", cap_raw), ("nopad_after", cap)):
        kc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        vc = jnp.asarray(rng.randn(8, hkv, w, d), jnp.bfloat16)
        qd = jnp.asarray(rng.randn(8, 4, 1, d), jnp.bfloat16)
        cl = jnp.full((8,), w, jnp.int32)
        fn = jax.jit(lambda q, k, v, c: swat_decode(q, k, v, c,
                                                    interpret=True))
        t = time_fn(fn, qd, kc, vc, cl, iters=iters, warmup=1)
        emit(f"kernel/decode_repad_{label}_w{w}", t, "interpret")
        out[f"us_{label}"] = t
    return out


def smoke(rng):
    """CI fast lane: no timing, only the shape/fusion invariants whose
    silent regressions this file exists to catch."""
    # 1. engine ring allocations must tile exactly (no pad-and-copy)
    from repro.core.layers import AttentionLayerCfg
    for window, g, la in [(64, 0, 0), (128, 4, 0), (255, 4, 3), (16, 0, 1)]:
        spec = AttentionSpec(kind="swat", window=window, num_global=g,
                             causal=True)
        acfg = AttentionLayerCfg(d_model=64, num_heads=4, num_kv_heads=2,
                                 head_dim=32, spec=spec)
        alloc = cache_allocation(acfg, 65536, la)
        blk, pads = decode_block_kv(alloc)
        assert not pads and alloc % blk == 0, (window, g, la, alloc, blk)

    # 2. fused insert+attend == jnp oracle; cache updates bitwise (broken
    #    input/output aliasing or slot arithmetic fails here)
    spec = AttentionSpec(kind="swat", window=24, num_global=4, causal=True)
    for group, t in [(1, 1), (4, 1), (4, 4)]:
        cap = spec.window + 1 + (t - 1) + spec.num_global
        w = _round_capacity(cap)
        b, hq = 3, group * HKV
        q = jnp.asarray(rng.randn(b, hq, t, D), jnp.float32)
        kc = jnp.asarray(rng.randn(b, HKV, w, D), jnp.float32)
        vc = jnp.asarray(rng.randn(b, HKV, w, D), jnp.float32)
        nk = jnp.asarray(rng.randn(b, HKV, t, D), jnp.float32)
        nv = jnp.asarray(rng.randn(b, HKV, t, D), jnp.float32)
        pos = jnp.asarray([0, 5, 3 * cap + 1][:b], jnp.int32)
        got = decode_attention(q, kc, vc, None, spec, impl="pallas",
                               new_kv=(nk, nv), pos=pos, ring_cap=cap,
                               interpret=True)
        want = decode_attention(q, kc, vc, None, spec, impl="ref",
                                new_kv=(nk, nv), pos=pos, ring_cap=cap)
        np.testing.assert_allclose(got[0], want[0], atol=2e-5, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))

    # 3. packed and unpacked layouts agree (plain mode)
    b, group, w = 2, 4, 256
    q, kc, vc, _, _, step = _decode_args(rng, b, group, w,
                                         dtype=jnp.float32)
    a = swat_decode(q, kc, vc, step, pack_gqa=True, interpret=True)
    bb = swat_decode(q, kc, vc, step, pack_gqa=False, interpret=True)
    np.testing.assert_allclose(a, bb, atol=2e-5, rtol=1e-4)

    # 4. perf-regression guard over the committed benchmark artifact: the
    #    flagship decode speedup must hold its floor, and every sub-1.0x
    #    shape must be on the tracked list — a NEW loss shape (or a
    #    stale/deleted artifact) fails CI instead of scrolling by.
    check_benchmark_artifact()

    # 5. static-analysis gate: the committed ANALYSIS.json (written by
    #    `python -m repro.launch.analyze --write`) must exist and report
    #    zero hot-path violations — a kernel change that un-donates the
    #    ring caches or leaks a collective into the decode scan refuses
    #    here even before the full `analyze --check` lane runs
    from repro.analysis import baselines
    baselines.check_artifact()

    # 6. resilience gate over the committed BENCH_serve.json: a clean
    #    serving run must have recorded ZERO degradation events (the
    #    always-compiled guards are bystanders) and both chaos drills
    #    must have actually fired — an artifact that says the engine
    #    quarantined slots on a clean run, or that a drill was a no-op,
    #    refuses here
    check_serve_resilience()

    # 7. prefix-sharing gate over the same artifact: the paged engine's
    #    shared-prefix workload must have recorded a prefill-work ratio
    #    below the 0.5 floor with identical tokens and real block dedup —
    #    a cache-contract change that silently disables sharing (or makes
    #    COW lossy) refuses here
    check_serve_prefix_sharing()

    # 8. telemetry gate over the same artifact: the metrics-on engine
    #    must have emitted bitwise-identical tokens at under 3% overhead
    #    with device counters matching host stats — instrumentation that
    #    perturbs decode or taxes the hot path refuses here
    check_serve_telemetry()
    print("[kernel_bench] smoke OK")


def check_serve_resilience(path=None):
    """Gate on BENCH_serve.json's `resilience` section (written by
    benchmarks/serve_bench.py, or merged by its --resilience-only mode):
    clean run event-free and all-ok; quarantine drill quarantined exactly
    one slot with healthy slots bitwise identical; pallas-failure drill
    fell back exactly once with every request still ok."""
    import json
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    with open(path) as f:
        payload = json.load(f)
    res = payload.get("resilience")
    assert res is not None, (
        "BENCH_serve.json has no `resilience` section — regenerate with "
        "benchmarks/serve_bench.py (--resilience-only merges just it)")
    clean = res["clean"]
    assert clean["events"] == 0 and clean["all_ok"], (
        f"clean serving run recorded unexpected degradation: {clean} — "
        "the fault guards fired without a fault plan; that is a real "
        "engine regression, not an artifact problem")
    q = res["quarantine_drill"]
    assert q["quarantined"] == 1 and q["healthy_bitwise_identical"], (
        f"quarantine drill did not behave: {q}")
    fb = res["pallas_fallback_drill"]
    assert fb["kernel_fallbacks"] == 1 and fb["all_ok"], (
        f"pallas-failure drill did not behave: {fb}")
    print(f"[kernel_bench] resilience gate: clean run event-free; "
          f"drills fired (quarantined={q['quarantined']}, "
          f"fallbacks={fb['kernel_fallbacks']})")


def check_serve_prefix_sharing(path=None):
    """Gate on BENCH_serve.json's `prefix_sharing` section (written by
    benchmarks/serve_bench.py): >= 8 shared-prefix requests, prefill-work
    ratio < 0.5, at least one prefix actually shared, pool blocks deduped
    at admission, tokens identical to the unshared paged engine."""
    import json
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    with open(path) as f:
        payload = json.load(f)
    ps = payload.get("prefix_sharing")
    assert ps is not None, (
        "BENCH_serve.json has no `prefix_sharing` section — regenerate "
        "with benchmarks/serve_bench.py")
    assert ps["requests"] >= 8, ps
    assert ps["identical_to_unshared"], (
        f"prefix sharing changed tokens: {ps} — COW or the shareable-"
        "block invariant is broken; that is an engine regression")
    ratio = ps["prefill_tokens"]["ratio"]
    assert ratio < 0.5, (
        f"prefix sharing saved too little prefill work (ratio {ratio}, "
        f"floor 0.5): {ps}")
    assert ps["prefix_prefills_shared"] >= 1, ps
    pool = ps["pool_blocks_at_admission"]
    assert pool["sharing"] < pool["baseline"], (
        f"prefix blocks did not dedup in the pool: {pool}")
    print(f"[kernel_bench] prefix-sharing gate: ratio {ratio} < 0.5 over "
          f"{ps['requests']} requests, pool {pool['sharing']} vs "
          f"{pool['baseline']} blocks, tokens identical")


def check_serve_telemetry(path=None):
    """Gate on BENCH_serve.json's `telemetry` section (written by
    benchmarks/serve_bench.py): the metrics-on batched engine must be
    bitwise identical to metrics-off, device counters must match the
    host-side stats, and the recorded throughput overhead must stay under
    3% (median-of-reps on both sides; the carry is a handful of donated
    int32 vectors, so a real tax here means the counters left the scan)."""
    import json
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    with open(path) as f:
        payload = json.load(f)
    tel = payload.get("telemetry")
    assert tel is not None, (
        "BENCH_serve.json has no `telemetry` section — regenerate with "
        "benchmarks/serve_bench.py")
    assert tel["identical"], (
        f"device metrics changed tokens: {tel} — the counter carry "
        "perturbed the decode math; that is an engine regression")
    assert tel["device_matches_host"], (
        f"device counters disagree with host stats: {tel}")
    assert tel["overhead_pct"] < 3.0, (
        f"metrics overhead {tel['overhead_pct']}% breaches the 3% "
        f"budget: {tel}")
    print(f"[kernel_bench] telemetry gate: tokens identical, counters "
          f"match, overhead {tel['overhead_pct']}% < 3%")


def check_benchmark_artifact(path=None):
    """Gate on the repo's BENCH_kernel.json (the artifact the timing run
    writes): flagship (B=32, group=8, W=4096) fused speedup >= the
    recorded `required` floor (2.0x), and no untracked regressions."""
    import json
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_kernel.json")
    with open(path) as f:
        payload = json.load(f)
    acc = payload["acceptance"]
    required = float(acc.get("required", 2.0))
    got = float(acc["decode_speedup_vs_pr2"])
    assert got >= required, (
        f"flagship decode speedup {got:.2f}x < {required:.1f}x at "
        f"{acc['shape']} — the fused hot path regressed (or the artifact "
        "was regenerated on a slower path); investigate before merging")
    losses = {(r["b"], r["group"], r["w"]): r["speedup"]
              for r in payload["decode_gqa"] if r["speedup"] < 1.0}
    untracked = set(losses) - EXPECTED_REGRESSIONS
    assert not untracked, (
        f"NEW decode loss shapes {sorted(untracked)} (speedups "
        f"{ {s: losses[s] for s in untracked} }) are not in "
        "EXPECTED_REGRESSIONS — either fix the regression or track it "
        "explicitly here with a ROADMAP pointer")
    recovered = EXPECTED_REGRESSIONS - {
        (r["b"], r["group"], r["w"]) for r in payload["decode_gqa"]
        if r["speedup"] < 1.0}
    missing = EXPECTED_REGRESSIONS - {
        (r["b"], r["group"], r["w"]) for r in payload["decode_gqa"]}
    print(f"[kernel_bench] artifact gate: flagship {got:.2f}x >= "
          f"{required:.1f}x; tracked losses "
          f"{sorted(set(losses) & EXPECTED_REGRESSIONS)}"
          + (f"; RECOVERED (untrack them): {sorted(recovered - missing)}"
             if recovered - missing else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: shape/fusion invariants only, no timing")
    ap.add_argument("--out", default="BENCH_kernel.json")
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()
    rng = np.random.RandomState(0)
    if args.smoke:
        smoke(rng)
        return

    payload = {
        "bench": "kernel", "interpret": True,
        "note": ("interpret-mode timings: relative before/after only — the "
                 "ratio tracks program count and per-step work, not TPU "
                 "wall time"),
        "decode_gqa": bench_decode_gqa(rng, GQA_SWEEP, args.iters),
        "decode_multi_token": bench_multi_token(rng, args.iters),
        "xla_banded": bench_xla_banded(rng, args.iters),
        "decode_repad": bench_repad(rng, args.iters),
    }
    b, g, w = ACCEPT_SHAPE
    row = next(r for r in payload["decode_gqa"]
               if (r["b"], r["group"], r["w"]) == ACCEPT_SHAPE)
    payload["acceptance"] = {
        "shape": f"B={b} group={g} W={w}",
        "decode_speedup_vs_pr2": row["speedup"],
        "required": 2.0,
        "pass": row["speedup"] >= 2.0,
    }
    write_json(args.out, payload)
    if not payload["acceptance"]["pass"]:
        print(f"[kernel_bench] FAIL: decode speedup {row['speedup']:.2f}x "
              "< 2x at the acceptance shape", file=sys.stderr)
        sys.exit(1)
    print(f"[kernel_bench] decode speedup at B={b} group={g} W={w}: "
          f"{row['speedup']:.2f}x (>= 2x required)")


if __name__ == "__main__":
    main()
