"""Serving engine benchmark: scan-based batched decode vs the seed engine's
per-token host sync, the mesh-sharded engine vs single-device, plus the
ring-cache memory claim.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch llama3.2-1b]
        [--requests 8 --slots 4 --new-tokens 64 --scan-steps 8]
        [--mesh 4x1 --force-devices 4]

Modes compared (same model, same requests, greedy, fixed seed):
  seed-style : scan_steps=1, one-prompt-at-a-time prefill — one host round
               trip per generated token (the seed ServingEngine behavior)
  batched    : batched padded prefill + lax.scan decode blocks — one host
               sync per scan_steps tokens
  sharded    : the batched engine under a --mesh device mesh (slot axis
               over 'data') — decode partitioned by XLA. On the default
               4x1 slot-parallel mesh every slot's math is device-local,
               so tokens must be IDENTICAL to the batched mode. Forced
               host CPU devices share the same silicon, so tok/s here
               measures partitioning overhead, not speedup — the sharded
               win is a real-multi-chip property.
  speculative: draft/verify/accept decode (k n-gram drafts verified per
               dispatch) vs the same engine at speculative=0, on a model
               briefly fitted to a repetitive corpus so greedy output has
               the self-similarity real workloads carry (random-init
               weights emit undraftable noise — recorded separately as
               the speculative_random diagnostic). Tokens must be
               IDENTICAL to sequential decode; the run fails below 1.3x.

Also prints ring-cache bytes (SWAT window spec) vs dense at the serving
context — the paper's Fig. 3 linear-memory claim applied to decode — and
writes the whole run to BENCH_serve.json (shapes, tok/s per mode, parity
flags) so future PRs have a machine-readable perf trajectory to diff.

A `prefix_sharing` section records the paged-KV shared-prefix workload
(>= 8 requests behind one system prompt: prefill work must drop below
0.5x, prefix pool blocks must dedup, tokens must stay identical), and a
`resilience` section records the fault-injection probes (clean-run
degradation events must be ZERO; the quarantine and pallas-fallback
drills must fire) — `kernel_bench --smoke` refuses on a bad section.
`--resilience-only` reruns just those probes and merges the section into
the existing artifact.

Each mode also carries a `latency` block (p50/p95/p99 TTFT / TPOT /
queue-delay in ms, from the engine's request tracer), and a `telemetry`
section records the device-counter A/B: the batched engine with the
metrics pytree compiled into the scan carry must emit bitwise-identical
tokens at under 3% throughput overhead (`kernel_bench --smoke` gates on
both), with the device counters matching the host-side stats.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))  # `python benchmarks/serve_bench.py` from anywhere

import numpy as np


def run_mode(cfg, params, reqs, *, scan_steps, batch_prefill, max_len,
             label, mesh=None, warm=True, speculative=0, draft=None,
             reps=1, donate=True, metrics=False):
    from repro.serving.engine import ServingEngine

    kw = {"donate": donate, "metrics": metrics}
    if speculative:
        kw.update(speculative=speculative, draft=draft)

    def once():
        eng = ServingEngine(cfg, params, batch_slots=ARGS.slots,
                            max_len=max_len, scan_steps=scan_steps,
                            batch_prefill=batch_prefill, mesh=mesh, **kw)
        t0 = time.perf_counter()
        results = eng.run(list(reqs))
        dt = time.perf_counter() - t0
        return results, dt, eng

    if warm:           # first run pays jit compiles for this mode's shapes
        once()
    # median over reps: 64-token runs finish in tens of ms, where scheduler
    # jitter swamps a single sample (speculative-vs-sequential especially)
    samples = sorted((once() for _ in range(reps)), key=lambda s: s[1])
    results, dt, eng = samples[len(samples) // 2]
    n = sum(len(r.tokens) for r in results)
    print(f"[serve_bench] {label:<16} {n:4d} tokens in {dt:6.2f}s "
          f"-> {n / dt:8.1f} tok/s")
    return results, n / dt, eng


def latency_of(eng):
    """p50/p95/p99 TTFT / TPOT / queue delay of one mode's median run, in
    milliseconds — the BENCH_serve.json `latency` rows (counts dropped:
    they equal the request count)."""
    summ = eng.tracer.latency_summary()
    return {field: {p: round(summ[field][p] * 1e3, 3)
                    for p in ("p50", "p95", "p99")}
            for field in ("ttft", "tpot", "queue_delay")}


def fit_selfsim(cfg, params, steps, Mod):
    """Fit the smoke model to a tiny repetitive corpus (seeded motifs,
    tiled) so greedy continuations carry the self-similarity real serving
    workloads have. Random-INIT weights emit near-chaotic trajectories no
    drafter can predict (acceptance ~0.1, recorded below as the
    speculative_random diagnostic), which says nothing about the engine —
    speculation is always benched on models whose output is predictable
    enough to draft. ~tens of seconds on CPU, fully deterministic."""
    import jax
    import jax.numpy as jnp

    rng = np.random.RandomState(7)
    motifs = [rng.randint(0, cfg.vocab_size, (int(l),)).astype(np.int32)
              for l in rng.randint(4, 13, size=16)]

    def batch_of(bs, seq=96):
        idx = rng.randint(0, len(motifs), size=bs)
        rows = [np.tile(motifs[i], seq // len(motifs[i]) + 1)[:seq]
                for i in idx]
        x = jnp.asarray(np.stack(rows).astype(np.int32))
        return {"tokens": x, "labels": x}

    try:
        import optax
        opt = optax.adam(3e-3)
    except ImportError:                      # plain momentum SGD fallback
        class _SGD:
            def init(self, p):
                return jax.tree.map(jnp.zeros_like, p)

            def update(self, g, m):
                m = jax.tree.map(lambda m, g: 0.9 * m + g, m, g)
                return jax.tree.map(lambda m: -0.05 * m, m), m
        import types
        optax = types.SimpleNamespace(apply_updates=lambda p, u: jax.tree.map(
            lambda p, u: p + u, p, u))
        opt = _SGD()
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (_, aux), g = jax.value_and_grad(Mod.loss_fn, has_aux=True)(
            params, cfg, batch)
        upd, state = opt.update(g, state)
        return optax.apply_updates(params, upd), state, aux["loss"]

    t0 = time.perf_counter()
    for _ in range(steps):
        params, state, loss = step(params, state, batch_of(16))
    print(f"[serve_bench] fit {steps} steps on motif corpus in "
          f"{time.perf_counter() - t0:.1f}s (loss {float(loss):.3f})")
    prompts = [np.tile(motifs[i % len(motifs)],
                       ARGS.prompt_len // len(motifs[i % len(motifs)]) + 1)
               [:ARGS.prompt_len].astype(np.int32)
               for i in range(ARGS.requests)]
    return params, prompts


def resilience_section(cfg, params, reqs):
    """Resilience probes -> the BENCH_serve.json `resilience` section that
    `kernel_bench --smoke` gates on: a CLEAN run must record zero
    degradation events (guards are bitwise-invisible bystanders), and the
    two drills — logit poison, Pallas dispatch failure — must actually
    fire (quarantine exactly one slot / fall back to the ref impl) while
    every healthy request stays token-identical. Returns (section, ok)."""
    from repro.serving import faults as F
    from repro.serving.engine import ServingEngine
    from repro.serving.faults import FaultPlan

    def once(faults=None, **kw):
        eng = ServingEngine(cfg, params, batch_slots=ARGS.slots,
                            max_len=ARGS.max_len,
                            scan_steps=ARGS.scan_steps,
                            faults=faults if faults is not None
                            else FaultPlan(), **kw)
        return eng, {r.rid: r for r in eng.run(list(reqs))}

    F.consume_events()
    _, clean = once()
    clean_events = [e["kind"] for e in F.consume_events()]
    clean_ok = all(r.status == "ok" for r in clean.values())
    print(f"[serve_bench] resilience/clean: all_ok={clean_ok}, "
          f"degradation_events={len(clean_events)} (must be 0)")

    qeng, chaos = once(FaultPlan(poison_logits=((0, 3, "nan"),)))
    qevents = [e["kind"] for e in F.consume_events()]
    healthy_identical = all(chaos[i].tokens == clean[i].tokens
                            for i in clean if chaos[i].status == "ok")
    print(f"[serve_bench] resilience/quarantine drill: "
          f"quarantined={qeng.stats['quarantined']}, healthy bitwise "
          f"identical={healthy_identical}")

    try:
        feng, fb = once(FaultPlan(fail_pallas_dispatch=True),
                        decode_impl="pallas")
    finally:
        F.clear_kernel_failure()
    fevents = [e["kind"] for e in F.consume_events()]
    fb_ok = all(r.status == "ok" for r in fb.values())
    fb_identical = all(fb[i].tokens == clean[i].tokens for i in clean)
    print(f"[serve_bench] resilience/pallas-failure drill: "
          f"kernel_fallbacks={feng.stats['kernel_fallbacks']}, impl now "
          f"{feng.decode_impl!r}, all ok={fb_ok}, tokens identical to ref "
          f"engine={fb_identical}")

    section = {
        "clean": {"events": len(clean_events), "all_ok": bool(clean_ok)},
        "quarantine_drill": {
            "quarantined": int(qeng.stats["quarantined"]),
            "healthy_bitwise_identical": bool(healthy_identical),
            "events": qevents},
        "pallas_fallback_drill": {
            "kernel_fallbacks": int(feng.stats["kernel_fallbacks"]),
            "all_ok": bool(fb_ok),
            "identical_to_ref": bool(fb_identical),
            "events": fevents},
    }
    ok = (clean_ok and not clean_events
          and qeng.stats["quarantined"] == 1 and healthy_identical
          and feng.stats["kernel_fallbacks"] == 1 and fb_ok
          and fb_identical)
    return section, ok


def prefix_sharing_section(cfg, params):
    """Paged-KV prefix sharing -> the BENCH_serve.json `prefix_sharing`
    section: >= 8 requests behind one shared system prompt, served by the
    paged engine with sharing off vs on. Sharing must cut prefill work
    below 0.5x (the prefix prefills ONCE), dedup the prefix blocks in the
    pool (cache bytes), and stay token-identical — copy-on-write covers
    the divergence. Returns (section, ok)."""
    import collections

    from repro.serving.engine import Request, ServingEngine

    n = max(8, ARGS.requests)
    rng = np.random.RandomState(13)
    sys_prompt = rng.randint(0, cfg.vocab_size, (96,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.randint(0, cfg.vocab_size, (16,)
                                           ).astype(np.int32)])
               for _ in range(n)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]

    def engine(share):
        return ServingEngine(cfg, params, batch_slots=n,
                             max_len=ARGS.max_len, kv_layout="paged",
                             prefill_chunk=32, share_prefix=share)

    def blocks_at_admission(eng):
        pend = collections.deque(reqs())
        eng._run_t0 = 0.0
        eng._admit(pend)
        used = eng.paged_stats()["blocks_in_use"]
        eng._run_t0 = None
        res = {r.rid: r for r in eng.run(list(pend)) + eng.take_completed()}
        return used, res

    off = engine(False)
    off_blocks, off_res = blocks_at_admission(off)
    on = engine(True)
    on_blocks, on_res = blocks_at_admission(on)

    identical = all(off_res[i].tokens == on_res[i].tokens for i in off_res)
    ratio = (on.stats["prefill_tokens_computed"]
             / max(off.stats["prefill_tokens_computed"], 1))
    # bytes per pool block: K+V rows for one block across every super-block
    # (shared pools are (S, NB, H, page, D); a block is one NB row)
    page_bytes = 0
    for c in on.caches.values():
        if isinstance(c, dict) and "pk" in c:
            pk = c["pk"]
            page_bytes = 2 * pk.dtype.itemsize * int(
                np.prod(pk.shape)) // pk.shape[1]
            break
    print(f"[serve_bench] prefix sharing ({n} reqs, 96-token system "
          f"prompt): prefill tokens {on.stats['prefill_tokens_computed']} "
          f"vs {off.stats['prefill_tokens_computed']} ({ratio:.2f}x, gate "
          f"< 0.5); pool blocks at admission {on_blocks} vs {off_blocks} "
          f"(~{(off_blocks - on_blocks) * page_bytes / 1e3:.1f}KB saved); "
          f"identical {identical}")
    section = {
        "requests": n, "system_prompt_len": 96, "suffix_len": 16,
        "prefill_tokens": {
            "sharing": int(on.stats["prefill_tokens_computed"]),
            "baseline": int(off.stats["prefill_tokens_computed"]),
            "ratio": round(ratio, 4)},
        "pool_blocks_at_admission": {
            "sharing": int(on_blocks), "baseline": int(off_blocks),
            "page_bytes": int(page_bytes)},
        "prefix_prefills_shared": int(on.stats["prefill_prefix_shared"]),
        "identical_to_unshared": bool(identical),
    }
    ok = (identical and ratio < 0.5
          and on.stats["prefill_prefix_shared"] >= 1
          and on_blocks < off_blocks)
    return section, ok


def main():
    global ARGS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--scan-steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--mesh", default="4x1",
                    help="sharded-mode mesh 'DxM' ('' disables the sharded "
                         "comparison)")
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force this many host CPU devices (0 = the mesh "
                         "size; must be set before jax initializes, which "
                         "is why this script imports jax late)")
    ap.add_argument("--speculative", type=int, default=4,
                    help="draft depth k for the speculative section "
                         "(0 disables it)")
    ap.add_argument("--fit-steps", type=int, default=200,
                    help="optimizer steps fitting the smoke model to the "
                         "self-similar corpus the speculative section "
                         "decodes")
    ap.add_argument("--spec-reps", type=int, default=5,
                    help="timing repetitions (median) for the "
                         "speculative/sequential comparison")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--resilience-only", action="store_true",
                    help="run just the resilience probes and MERGE the "
                         "`resilience` section into an existing --out "
                         "artifact (the section kernel_bench --smoke "
                         "gates on)")
    ARGS = ap.parse_args()

    mesh_dims = (tuple(int(x) for x in ARGS.mesh.split("x"))
                 if ARGS.mesh else ())
    need = ARGS.force_devices or (int(np.prod(mesh_dims)) if mesh_dims else 0)
    if need > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.launch.mesh import parse_mesh
    from repro.serving.engine import Request, ring_cache_bytes

    cfg = with_swat(get_smoke_config(ARGS.arch), window=ARGS.window,
                    num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, (ARGS.prompt_len,)).astype(np.int32),
        max_new_tokens=ARGS.new_tokens) for i in range(ARGS.requests)]

    if ARGS.resilience_only:
        import json

        section, res_ok = resilience_section(cfg, params, reqs)
        existing = {}
        if os.path.exists(ARGS.out):
            with open(ARGS.out) as f:
                existing = json.load(f)
        existing["resilience"] = section
        from benchmarks.common import write_json
        write_json(ARGS.out, existing)
        if not res_ok:
            print("[serve_bench] FAIL: resilience probes (clean-run "
                  "events or a drill that did not fire)", file=sys.stderr)
            sys.exit(1)
        return

    base, base_tps, base_eng = run_mode(cfg, params, reqs, scan_steps=1,
                                        batch_prefill=False,
                                        max_len=ARGS.max_len,
                                        label="seed-style")
    fast, fast_tps, fast_eng = run_mode(cfg, params, reqs,
                                        scan_steps=ARGS.scan_steps,
                                        batch_prefill=True,
                                        max_len=ARGS.max_len,
                                        label="batched")

    same = all(a.tokens == b.tokens for a, b in zip(base, fast))
    print(f"[serve_bench] outputs identical: {same}; "
          f"speedup {fast_tps / base_tps:.2f}x "
          f"(scan_steps={ARGS.scan_steps} + batched prefill)")

    # donation A/B: the same batched engine with buffer donation disabled —
    # XLA materializes a fresh ring cache on every decode dispatch instead
    # of aliasing it in place. Token identity is the correctness contract
    # (donation must never change results); the per-block latency delta is
    # what swatlint's donation rule guards. Block latency is derived from
    # steady-state throughput at the tokens-per-dispatch granularity.
    don, don_tps, _ = run_mode(cfg, params, reqs,
                               scan_steps=ARGS.scan_steps,
                               batch_prefill=True, max_len=ARGS.max_len,
                               label="batched/donate", reps=ARGS.spec_reps)
    undon, undon_tps, _ = run_mode(cfg, params, reqs,
                                   scan_steps=ARGS.scan_steps,
                                   batch_prefill=True, max_len=ARGS.max_len,
                                   label="batched/no-donate", donate=False,
                                   reps=ARGS.spec_reps)
    don_same = all(a.tokens == b.tokens for a, b in zip(don, undon))
    blk = 1000.0 * ARGS.slots * ARGS.scan_steps   # tokens per scan dispatch
    print(f"[serve_bench] donation A/B: identical {don_same}; block "
          f"{blk / don_tps:.2f}ms donated vs {blk / undon_tps:.2f}ms "
          f"copied ({don_tps / undon_tps:.2f}x; smoke-scale caches — the "
          f"copy removed is ~ring bytes per block, see ring_cache)")

    # telemetry A/B: the same batched engine with the device counter pytree
    # compiled into the scan carry (swatscope layer 1). Tokens must stay
    # bitwise identical — counters are donated int32 carries read only at
    # block boundaries — and the throughput tax must stay under the 3%
    # gate kernel_bench --smoke enforces. The metrics-OFF side reuses the
    # donated median above (same engine parameters, same reps).
    met, met_tps, met_eng = run_mode(cfg, params, reqs,
                                     scan_steps=ARGS.scan_steps,
                                     batch_prefill=True,
                                     max_len=ARGS.max_len,
                                     label="batched/metrics", metrics=True,
                                     reps=ARGS.spec_reps)
    met_same = all(a.tokens == b.tokens for a, b in zip(don, met))
    overhead_pct = 100.0 * (1.0 - met_tps / don_tps)
    dev = met_eng.device_metrics()
    counters_match = dev["tokens"] == met_eng.stats["tokens_emitted"]
    print(f"[serve_bench] telemetry A/B: identical {met_same}; "
          f"{met_tps:.1f} vs {don_tps:.1f} tok/s "
          f"({overhead_pct:+.2f}% overhead, gate < 3); device "
          f"tokens={dev['tokens']} (host {met_eng.stats['tokens_emitted']}, "
          f"match={counters_match})")

    payload = {
        "bench": "serve", "arch": ARGS.arch,
        "requests": ARGS.requests, "slots": ARGS.slots,
        "prompt_len": ARGS.prompt_len, "new_tokens": ARGS.new_tokens,
        "scan_steps": ARGS.scan_steps, "window": ARGS.window,
        "modes": {"seed_style": {"tok_s": round(base_tps, 2),
                                 "latency": latency_of(base_eng)},
                  "batched": {"tok_s": round(fast_tps, 2),
                              "speedup_vs_seed":
                                  round(fast_tps / base_tps, 3),
                              "latency": latency_of(fast_eng)}},
        "outputs_identical": bool(same),
        "donation_ab": {
            "donated": {"tok_s": round(don_tps, 2),
                        "block_ms": round(blk / don_tps, 3)},
            "copied": {"tok_s": round(undon_tps, 2),
                       "block_ms": round(blk / undon_tps, 3)},
            "speedup": round(don_tps / undon_tps, 3),
            "identical": bool(don_same),
            "note": ("smoke-scale model on CPU: the removed per-block "
                     "copy is ~the ring-cache bytes, so the delta grows "
                     "with window*layers*slots; identity is the gate"),
        },
        "telemetry": {
            "metrics_on": {"tok_s": round(met_tps, 2),
                           "latency": latency_of(met_eng)},
            "metrics_off": {"tok_s": round(don_tps, 2)},
            "overhead_pct": round(overhead_pct, 3),
            "identical": bool(met_same),
            "device_counters": {k: int(v) for k, v in sorted(dev.items())},
            "device_matches_host": bool(counters_match),
        },
    }
    shard_same = True
    if mesh_dims and jax.device_count() < int(np.prod(mesh_dims)):
        # e.g. a non-CPU default backend: the forced-host-device flag only
        # adds CPU-platform devices. Never skip silently — this bench is
        # advertised as the sharded-correctness gate.
        print(f"[serve_bench] WARN: sharded comparison SKIPPED — mesh "
              f"{ARGS.mesh} needs {int(np.prod(mesh_dims))} devices, "
              f"have {jax.device_count()} ({jax.default_backend()})",
              file=sys.stderr)
    elif mesh_dims:
        mesh = parse_mesh(ARGS.mesh)
        shard, shard_tps, shard_eng = run_mode(
            cfg, params, reqs, scan_steps=ARGS.scan_steps,
            batch_prefill=True, max_len=ARGS.max_len,
            label=f"sharded/{ARGS.mesh}", mesh=mesh)
        identical = all(a.tokens == b.tokens
                        for a, b in zip(fast, shard))
        # token-exactness is only guaranteed for slot-parallel meshes
        # (model dim 1): TP psums a bf16 contraction in a different order,
        # so near-tied draws may legitimately flip (see serving README)
        slot_parallel = len(mesh_dims) < 2 or mesh_dims[-1] == 1
        shard_same = identical or not slot_parallel
        note = ("" if slot_parallel
                else " (TP mesh: exactness not required, see README)")
        print(f"[serve_bench] sharded vs batched: identical {identical}"
              f"{note}; {shard_tps:.1f} vs {fast_tps:.1f} tok/s "
              f"({shard_tps / fast_tps:.2f}x on forced-{need}-device CPU — "
              f"partitioning overhead, not silicon)")
        payload["modes"]["sharded"] = {
            "mesh": ARGS.mesh, "tok_s": round(shard_tps, 2),
            "identical_to_batched": bool(identical),
            "slot_parallel": bool(slot_parallel),
            "latency": latency_of(shard_eng)}

    # ------------------------------------------------- speculative decode --
    spec_ok = True
    if ARGS.speculative:
        from repro.serving.drafter import NGramDrafter

        draft = NGramDrafter(max_ngram=3, history=64)
        # diagnostic first: speculation on the incompressible random-token
        # workload above. Acceptance collapses and the k-wide verify is a
        # pure compute tax — the expected, recorded loss that motivates the
        # self-similar workload below.
        _, rand_tps, rand_eng = run_mode(
            cfg, params, reqs, scan_steps=ARGS.scan_steps,
            batch_prefill=True, max_len=ARGS.max_len,
            label="spec/random", speculative=ARGS.speculative, draft=draft)
        print(f"[serve_bench] spec on random tokens: acceptance "
              f"{rand_eng.acceptance_rate:.3f} -> "
              f"{rand_tps / fast_tps:.2f}x vs batched (expected loss)")

        fit_params, fit_prompts = fit_selfsim(cfg, params, ARGS.fit_steps,
                                              Mod)
        fit_reqs = [Request(rid=i, prompt=p,
                            max_new_tokens=ARGS.new_tokens)
                    for i, p in enumerate(fit_prompts)]
        seqr, seq_tps, seq_eng = run_mode(
            cfg, fit_params, fit_reqs, scan_steps=ARGS.scan_steps,
            batch_prefill=True, max_len=ARGS.max_len,
            label="sequential/fit", reps=ARGS.spec_reps)
        specr, spec_tps, spec_eng = run_mode(
            cfg, fit_params, fit_reqs, scan_steps=ARGS.scan_steps,
            batch_prefill=True, max_len=ARGS.max_len,
            label=f"speculative/k={ARGS.speculative}",
            speculative=ARGS.speculative, draft=draft, reps=ARGS.spec_reps)
        spec_same = all(a.tokens == b.tokens for a, b in zip(seqr, specr))
        spec_speedup = spec_tps / seq_tps
        print(f"[serve_bench] speculative vs sequential: identical "
              f"{spec_same}; {spec_speedup:.2f}x at acceptance "
              f"{spec_eng.acceptance_rate:.3f} "
              f"({spec_eng.stats['spec_steps']} verify steps for "
              f"{spec_eng.stats['tokens_emitted']} tokens)")
        payload["modes"]["sequential_selfsim"] = {
            "tok_s": round(seq_tps, 2), "fit_steps": ARGS.fit_steps,
            "latency": latency_of(seq_eng)}
        payload["modes"]["speculative"] = {
            "tok_s": round(spec_tps, 2),
            "latency": latency_of(spec_eng),
            "speedup_vs_sequential": round(spec_speedup, 3),
            "acceptance_rate": round(spec_eng.acceptance_rate, 4),
            "k": ARGS.speculative,
            "draft": {"kind": "ngram", "max_ngram": draft.max_ngram,
                      "history": draft.history},
            "identical_to_sequential": bool(spec_same),
            "verify_steps": spec_eng.stats["spec_steps"],
        }
        payload["modes"]["speculative_random"] = {
            "tok_s": round(rand_tps, 2),
            "acceptance_rate": round(rand_eng.acceptance_rate, 4),
            "expected_loss": True,
        }
        spec_ok = spec_same and spec_speedup >= 1.3

    dense = get_smoke_config(ARGS.arch)
    ctx = 65536
    ring = ring_cache_bytes(cfg, ARGS.slots, ctx)
    dn = ring_cache_bytes(dense, ARGS.slots, ctx)
    print(f"[serve_bench] decode cache @ {ctx} ctx, {ARGS.slots} slots: "
          f"ring {ring / 1e6:.2f}MB vs dense {dn / 1e6:.2f}MB "
          f"({dn / max(ring, 1):.0f}x)")
    payload["ring_cache"] = {"context": ctx, "ring_bytes": ring,
                             "dense_bytes": dn,
                             "ratio": round(dn / max(ring, 1), 1)}
    payload["prefix_sharing"], share_ok = prefix_sharing_section(cfg, params)
    payload["resilience"], res_ok = resilience_section(cfg, params, reqs)
    from benchmarks.common import write_json
    write_json(ARGS.out, payload)
    if not same:
        print("[serve_bench] FAIL: modes disagree", file=sys.stderr)
        sys.exit(1)
    if not don_same:
        print("[serve_bench] FAIL: donation changed tokens", file=sys.stderr)
        sys.exit(1)
    if not met_same or not counters_match:
        print("[serve_bench] FAIL: device metrics changed tokens or "
              "disagree with host stats", file=sys.stderr)
        sys.exit(1)
    if not shard_same:
        print("[serve_bench] FAIL: sharded mode disagrees", file=sys.stderr)
        sys.exit(1)
    if fast_tps <= base_tps:
        print("[serve_bench] FAIL: batched mode not faster", file=sys.stderr)
        sys.exit(1)
    if not spec_ok:
        print("[serve_bench] FAIL: speculative decode below the 1.3x bar "
              "or not token-identical", file=sys.stderr)
        sys.exit(1)
    if not share_ok:
        print("[serve_bench] FAIL: prefix sharing above the 0.5x prefill "
              "bar, no block dedup, or not token-identical",
              file=sys.stderr)
        sys.exit(1)
    if not res_ok:
        print("[serve_bench] FAIL: resilience probes (clean-run events "
              "or a drill that did not fire)", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
