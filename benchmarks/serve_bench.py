"""Serving engine benchmark: scan-based batched decode vs the seed engine's
per-token host sync, the mesh-sharded engine vs single-device, plus the
ring-cache memory claim.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch llama3.2-1b]
        [--requests 8 --slots 4 --new-tokens 64 --scan-steps 8]
        [--mesh 4x1 --force-devices 4]

Modes compared (same model, same requests, greedy, fixed seed):
  seed-style : scan_steps=1, one-prompt-at-a-time prefill — one host round
               trip per generated token (the seed ServingEngine behavior)
  batched    : batched padded prefill + lax.scan decode blocks — one host
               sync per scan_steps tokens
  sharded    : the batched engine under a --mesh device mesh (slot axis
               over 'data') — decode partitioned by XLA. On the default
               4x1 slot-parallel mesh every slot's math is device-local,
               so tokens must be IDENTICAL to the batched mode. Forced
               host CPU devices share the same silicon, so tok/s here
               measures partitioning overhead, not speedup — the sharded
               win is a real-multi-chip property.

Also prints ring-cache bytes (SWAT window spec) vs dense at the serving
context — the paper's Fig. 3 linear-memory claim applied to decode — and
writes the whole run to BENCH_serve.json (shapes, tok/s per mode, parity
flags) so future PRs have a machine-readable perf trajectory to diff.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))  # `python benchmarks/serve_bench.py` from anywhere

import numpy as np


def run_mode(cfg, params, reqs, *, scan_steps, batch_prefill, max_len,
             label, mesh=None, warm=True):
    from repro.serving.engine import ServingEngine

    def once():
        eng = ServingEngine(cfg, params, batch_slots=ARGS.slots,
                            max_len=max_len, scan_steps=scan_steps,
                            batch_prefill=batch_prefill, mesh=mesh)
        t0 = time.perf_counter()
        results = eng.run(list(reqs))
        dt = time.perf_counter() - t0
        return results, dt

    if warm:           # first run pays jit compiles for this mode's shapes
        once()
    results, dt = once()
    n = sum(len(r.tokens) for r in results)
    print(f"[serve_bench] {label:<10} {n:4d} tokens in {dt:6.2f}s "
          f"-> {n / dt:8.1f} tok/s")
    return results, n / dt


def main():
    global ARGS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--scan-steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--mesh", default="4x1",
                    help="sharded-mode mesh 'DxM' ('' disables the sharded "
                         "comparison)")
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force this many host CPU devices (0 = the mesh "
                         "size; must be set before jax initializes, which "
                         "is why this script imports jax late)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ARGS = ap.parse_args()

    mesh_dims = (tuple(int(x) for x in ARGS.mesh.split("x"))
                 if ARGS.mesh else ())
    need = ARGS.force_devices or (int(np.prod(mesh_dims)) if mesh_dims else 0)
    if need > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.launch.mesh import parse_mesh
    from repro.serving.engine import Request, ring_cache_bytes

    cfg = with_swat(get_smoke_config(ARGS.arch), window=ARGS.window,
                    num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, (ARGS.prompt_len,)).astype(np.int32),
        max_new_tokens=ARGS.new_tokens) for i in range(ARGS.requests)]

    base, base_tps = run_mode(cfg, params, reqs, scan_steps=1,
                              batch_prefill=False, max_len=ARGS.max_len,
                              label="seed-style")
    fast, fast_tps = run_mode(cfg, params, reqs, scan_steps=ARGS.scan_steps,
                              batch_prefill=True, max_len=ARGS.max_len,
                              label="batched")

    same = all(a.tokens == b.tokens for a, b in zip(base, fast))
    print(f"[serve_bench] outputs identical: {same}; "
          f"speedup {fast_tps / base_tps:.2f}x "
          f"(scan_steps={ARGS.scan_steps} + batched prefill)")

    payload = {
        "bench": "serve", "arch": ARGS.arch,
        "requests": ARGS.requests, "slots": ARGS.slots,
        "prompt_len": ARGS.prompt_len, "new_tokens": ARGS.new_tokens,
        "scan_steps": ARGS.scan_steps, "window": ARGS.window,
        "modes": {"seed_style": {"tok_s": round(base_tps, 2)},
                  "batched": {"tok_s": round(fast_tps, 2),
                              "speedup_vs_seed":
                                  round(fast_tps / base_tps, 3)}},
        "outputs_identical": bool(same),
    }
    shard_same = True
    if mesh_dims and jax.device_count() < int(np.prod(mesh_dims)):
        # e.g. a non-CPU default backend: the forced-host-device flag only
        # adds CPU-platform devices. Never skip silently — this bench is
        # advertised as the sharded-correctness gate.
        print(f"[serve_bench] WARN: sharded comparison SKIPPED — mesh "
              f"{ARGS.mesh} needs {int(np.prod(mesh_dims))} devices, "
              f"have {jax.device_count()} ({jax.default_backend()})",
              file=sys.stderr)
    elif mesh_dims:
        mesh = parse_mesh(ARGS.mesh)
        shard, shard_tps = run_mode(
            cfg, params, reqs, scan_steps=ARGS.scan_steps,
            batch_prefill=True, max_len=ARGS.max_len,
            label=f"sharded/{ARGS.mesh}", mesh=mesh)
        identical = all(a.tokens == b.tokens
                        for a, b in zip(fast, shard))
        # token-exactness is only guaranteed for slot-parallel meshes
        # (model dim 1): TP psums a bf16 contraction in a different order,
        # so near-tied draws may legitimately flip (see serving README)
        slot_parallel = len(mesh_dims) < 2 or mesh_dims[-1] == 1
        shard_same = identical or not slot_parallel
        note = ("" if slot_parallel
                else " (TP mesh: exactness not required, see README)")
        print(f"[serve_bench] sharded vs batched: identical {identical}"
              f"{note}; {shard_tps:.1f} vs {fast_tps:.1f} tok/s "
              f"({shard_tps / fast_tps:.2f}x on forced-{need}-device CPU — "
              f"partitioning overhead, not silicon)")
        payload["modes"]["sharded"] = {
            "mesh": ARGS.mesh, "tok_s": round(shard_tps, 2),
            "identical_to_batched": bool(identical),
            "slot_parallel": bool(slot_parallel)}

    dense = get_smoke_config(ARGS.arch)
    ctx = 65536
    ring = ring_cache_bytes(cfg, ARGS.slots, ctx)
    dn = ring_cache_bytes(dense, ARGS.slots, ctx)
    print(f"[serve_bench] decode cache @ {ctx} ctx, {ARGS.slots} slots: "
          f"ring {ring / 1e6:.2f}MB vs dense {dn / 1e6:.2f}MB "
          f"({dn / max(ring, 1):.0f}x)")
    payload["ring_cache"] = {"context": ctx, "ring_bytes": ring,
                             "dense_bytes": dn,
                             "ratio": round(dn / max(ring, 1), 1)}
    from benchmarks.common import write_json
    write_json(ARGS.out, payload)
    if not same:
        print("[serve_bench] FAIL: modes disagree", file=sys.stderr)
        sys.exit(1)
    if not shard_same:
        print("[serve_bench] FAIL: sharded mode disagrees", file=sys.stderr)
        sys.exit(1)
    if fast_tps <= base_tps:
        print("[serve_bench] FAIL: batched mode not faster", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
