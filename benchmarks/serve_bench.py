"""Serving engine benchmark: scan-based batched decode vs the seed engine's
per-token host sync, plus the ring-cache memory claim.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch llama3.2-1b]
        [--requests 8 --slots 4 --new-tokens 64 --scan-steps 8]

Modes compared (same model, same requests, greedy):
  seed-style : scan_steps=1, one-prompt-at-a-time prefill — one host round
               trip per generated token (the seed ServingEngine behavior)
  batched    : batched padded prefill + lax.scan decode blocks — one host
               sync per scan_steps tokens

Also prints ring-cache bytes (SWAT window spec) vs dense at the serving
context — the paper's Fig. 3 linear-memory claim applied to decode.
"""
import argparse
import sys
import time

import jax
import numpy as np


def run_mode(cfg, params, reqs, *, scan_steps, batch_prefill, max_len,
             label, warm=True):
    from repro.serving.engine import ServingEngine

    def once():
        eng = ServingEngine(cfg, params, batch_slots=ARGS.slots,
                            max_len=max_len, scan_steps=scan_steps,
                            batch_prefill=batch_prefill)
        t0 = time.perf_counter()
        results = eng.run(list(reqs))
        dt = time.perf_counter() - t0
        return results, dt

    if warm:           # first run pays jit compiles for this mode's shapes
        once()
    results, dt = once()
    n = sum(len(r.tokens) for r in results)
    print(f"[serve_bench] {label:<10} {n:4d} tokens in {dt:6.2f}s "
          f"-> {n / dt:8.1f} tok/s")
    return results, n / dt


def main():
    global ARGS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--scan-steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--window", type=int, default=64)
    ARGS = ap.parse_args()

    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.serving.engine import Request, ring_cache_bytes

    cfg = with_swat(get_smoke_config(ARGS.arch), window=ARGS.window,
                    num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, (ARGS.prompt_len,)).astype(np.int32),
        max_new_tokens=ARGS.new_tokens) for i in range(ARGS.requests)]

    base, base_tps = run_mode(cfg, params, reqs, scan_steps=1,
                              batch_prefill=False, max_len=ARGS.max_len,
                              label="seed-style")
    fast, fast_tps = run_mode(cfg, params, reqs, scan_steps=ARGS.scan_steps,
                              batch_prefill=True, max_len=ARGS.max_len,
                              label="batched")

    same = all(a.tokens == b.tokens for a, b in zip(base, fast))
    print(f"[serve_bench] outputs identical: {same}; "
          f"speedup {fast_tps / base_tps:.2f}x "
          f"(scan_steps={ARGS.scan_steps} + batched prefill)")

    dense = get_smoke_config(ARGS.arch)
    ctx = 65536
    ring = ring_cache_bytes(cfg, ARGS.slots, ctx)
    dn = ring_cache_bytes(dense, ARGS.slots, ctx)
    print(f"[serve_bench] decode cache @ {ctx} ctx, {ARGS.slots} slots: "
          f"ring {ring / 1e6:.2f}MB vs dense {dn / 1e6:.2f}MB "
          f"({dn / max(ring, 1):.0f}x)")
    if not same:
        print("[serve_bench] FAIL: modes disagree", file=sys.stderr)
        sys.exit(1)
    if fast_tps <= base_tps:
        print("[serve_bench] FAIL: batched mode not faster", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
