"""Paper Fig. 3: execution time and memory vs input length for Dense,
Sliding-Chunks and SWAT. Wall-time measured on CPU via the XLA paths
(relative scaling is the claim; absolute numbers are CPU); memory is the
analytic decode-cache/S-matrix footprint (exact byte counts).
"""
import jax.numpy as jnp
import numpy as np
import jax

from repro.core.types import AttentionSpec
from repro.kernels.ops import swat_attention
from benchmarks.common import emit, time_fn

W = 128
HEADS, D = 4, 64


def run(seq, impl, spec):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, HEADS, seq, D), jnp.float32)
    k = jnp.asarray(rng.randn(1, HEADS, seq, D), jnp.float32)
    v = jnp.asarray(rng.randn(1, HEADS, seq, D), jnp.float32)
    fn = jax.jit(lambda q, k, v: swat_attention(q, k, v, spec, impl=impl))
    return time_fn(fn, q, k, v, iters=3, warmup=1)


def main():
    dense = AttentionSpec(kind="dense", causal=False)
    swat = AttentionSpec(kind="swat", window=W, causal=False)
    chunks = AttentionSpec(kind="sliding_chunks", window=W, causal=False)
    base = {}
    for seq in (1024, 2048, 4096, 8192):
        t_dense = run(seq, "xla", dense)
        t_swat = run(seq, "xla", swat)
        t_chunks = run(seq, "sliding_chunks", chunks)
        base.setdefault("dense", t_dense)
        base.setdefault("swat", t_swat)
        emit(f"fig3/time_dense/seq{seq}", t_dense,
             f"x{t_dense / base['dense']:.2f}_vs_1k")
        emit(f"fig3/time_swat/seq{seq}", t_swat,
             f"x{t_swat / base['swat']:.2f}_vs_1k")
        emit(f"fig3/time_chunks/seq{seq}", t_chunks,
             f"speedup_swat={t_chunks / t_swat:.2f}")
        # memory: S' matrix bytes (fp32) if materialized
        mem_dense = seq * seq * 4 * HEADS
        mem_swat = seq * (2 * W + 1) * 4 * HEADS
        emit(f"fig3/mem_dense_MB/seq{seq}", 0.0, f"{mem_dense / 1e6:.1f}")
        emit(f"fig3/mem_swat_MB/seq{seq}", 0.0, f"{mem_swat / 1e6:.1f}")


if __name__ == "__main__":
    main()
