"""Render the dry-run record directory as the §Dry-run / §Roofline tables."""
import json
import sys
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(pattern: str = ""):
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        if pattern and pattern not in f.name:
            continue
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_row(r):
    ro = r["roofline"]
    mem = r["memory"].get("temp_size_in_bytes", 0) / 1e9
    return (f"| {r['arch']:<22} | {r['shape']:<11} | {r['mesh']:<6} "
            f"| {ro['compute_s']:.3e} | {ro['memory_s']:.3e} "
            f"| {ro['collective_s']:.3e} | {ro['dominant']:<10} "
            f"| {ro['useful_flops_ratio']:.2f} | {ro['roofline_fraction']:.3f} "
            f"| {mem:7.1f} | {r['compile_s']:7.1f} |")


HEADER = ("| arch | shape | mesh | compute_s | memory_s | collective_s "
          "| dominant | useful | roof_frac | temp_GB | compile_s |")
SEP = "|" + "---|" * 11


def main():
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    recs = load(pattern)
    print(HEADER)
    print(SEP)
    for r in recs:
        print(fmt_row(r))
    print(f"\n{len(recs)} cells")


if __name__ == "__main__":
    main()
