"""Regenerate the auto tables in EXPERIMENTS.md from experiments/dryrun/*.

Everything between `<!-- AUTO:name -->` / `<!-- /AUTO:name -->` markers is
rewritten; hand-written analysis outside the markers is preserved.

Usage: PYTHONPATH=src python -m benchmarks.render_experiments
"""
import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load():
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        r["_file"] = f.name
        r["_variant"] = "+swat" if "+swat" in f.name else ""
        recs.append(r)
    return recs


def _fmt_s(x):
    return f"{x:.3g}"


def roofline_table(recs, mesh="single", variant="", tag=""):
    rows = [r for r in recs
            if r["mesh"] == mesh and r["_variant"] == variant
            and r.get("tag", "") == tag and r.get("profile", "tp") == "tp"]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful FLOPs | roofline frac | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        mem = r["memory"].get("temp_size_in_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(ro['compute_s'])} "
            f"| {_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} "
            f"| {ro['dominant']} | {ro['useful_flops_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} | {mem:.1f} |")
    out.append("")
    out.append(f"_{len(rows)} cells._")
    return "\n".join(out)


def dryrun_table(recs):
    rows = sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(
        r["shape"], 9), r["mesh"], r["_variant"], r.get("tag", "")))
    out = ["| arch | shape | mesh | variant | devices | compile_s | rolled "
           "| args GB/dev | temp GB/dev | collectives (count) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        mem = r["memory"]
        coll = ", ".join(f"{k}:{v}" for k, v in sorted(
            ro["collective_counts"].items()))
        var = (r["_variant"] + (" " + r.get("tag", "") if r.get("tag") else "")
               + (" " + r["profile"] if r.get("profile", "tp") != "tp"
                  else "")).strip() or "faithful"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {var} "
            f"| {r['devices']} | {r['compile_s']:.0f} "
            f"| {'' if r.get('unrolled', True) else 'yes'} "
            f"| {mem.get('argument_size_in_bytes', 0) / 1e9:.1f} "
            f"| {mem.get('temp_size_in_bytes', 0) / 1e9:.1f} | {coll} |")
    out.append("")
    out.append(f"_{len(rows)} dry-run records "
               f"(single pod = 256 chips (16,16); multi-pod = 512 chips "
               f"(2,16,16))._")
    return "\n".join(out)


def swat_table(recs):
    """Paper-faithful vs +swat variant comparison (same arch x shape)."""
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in recs
            if not r["_variant"] and not r.get("tag")
            and r.get("profile", "tp") == "tp"}
    out = ["| arch | shape | metric | faithful (dense) | +swat window "
           "| gain |", "|---|---|---|---|---|---|"]
    n = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["_variant"] != "+swat" or r.get("tag"):
            continue
        b = base.get((r["arch"].replace("+swat", ""), r["shape"], r["mesh"]))
        if not b:
            continue
        for metric in ("compute_s", "memory_s", "collective_s"):
            bv, sv = b["roofline"][metric], r["roofline"][metric]
            if bv <= 0:
                continue
            out.append(f"| {r['arch']} | {r['shape']} | {metric} "
                       f"| {_fmt_s(bv)} | {_fmt_s(sv)} "
                       f"| {bv / max(sv, 1e-12):.1f}x |")
        n += 1
    out.append("")
    out.append(f"_{n} (arch x shape) pairs with both variants lowered._")
    return "\n".join(out)


def render(text: str, name: str, body: str) -> str:
    pat = re.compile(rf"(<!-- AUTO:{name} -->).*?(<!-- /AUTO:{name} -->)",
                     re.S)
    if not pat.search(text):
        raise SystemExit(f"marker AUTO:{name} not found in EXPERIMENTS.md")
    return pat.sub(lambda m: f"{m.group(1)}\n{body}\n{m.group(2)}", text)


def main():
    recs = load()
    text = EXP.read_text()
    text = render(text, "dryrun", dryrun_table(recs))
    text = render(text, "roofline_single", roofline_table(recs, "single"))
    text = render(text, "swat_variant", swat_table(recs))
    EXP.write_text(text)
    print(f"EXPERIMENTS.md refreshed from {len(recs)} records")


if __name__ == "__main__":
    main()
