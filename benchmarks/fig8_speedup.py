"""Paper Figs. 8-9: SWAT speedup / energy efficiency vs baselines across
sequence length. Hardware-faithful substitution (DESIGN.md §7): the Butterfly
FPGA baseline is not reproducible offline, so the baselines here are the two
software baselines the paper also measures (dense, sliding-chunks), with
  speedup  := measured CPU wall-time ratio (XLA paths, same machine)
  energy   := FLOP ratio (energy ∝ work at fixed silicon; the paper's
              energy-per-attention follows compute time on both devices)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AttentionSpec
from repro.kernels.ops import swat_attention
from benchmarks.common import emit, time_fn

W = 256
HEADS, D = 4, 64


def t_of(impl, spec, seq):
    rng = np.random.RandomState(0)
    mk = lambda: jnp.asarray(rng.randn(1, HEADS, seq, D), jnp.float32)
    q, k, v = mk(), mk(), mk()
    fn = jax.jit(lambda q, k, v: swat_attention(q, k, v, spec, impl=impl))
    return time_fn(fn, q, k, v, iters=3, warmup=1)


def main():
    dense = AttentionSpec(kind="dense", causal=False)
    swat = AttentionSpec(kind="swat", window=W, causal=False)
    chunks = AttentionSpec(kind="sliding_chunks", window=W, causal=False)
    for seq in (1024, 4096, 16384):
        ts = t_of("xla", swat, seq)
        td = t_of("xla", dense, seq)
        tc = t_of("sliding_chunks", chunks, seq)
        emit(f"fig8/speedup_vs_dense/seq{seq}", ts, f"{td / ts:.2f}x")
        emit(f"fig8/speedup_vs_chunks/seq{seq}", ts, f"{tc / ts:.2f}x")
        # energy proxy: FLOP ratios
        f_dense = seq
        f_swat = min(seq, 2 * W + 1)
        f_chunks = min(seq, 2 * W) * 2
        emit(f"fig9/energy_gain_vs_dense/seq{seq}", 0.0,
             f"{f_dense / f_swat:.2f}x")
        emit(f"fig9/energy_gain_vs_chunks/seq{seq}", 0.0,
             f"{f_chunks / f_swat:.2f}x")


if __name__ == "__main__":
    main()
