"""Fault-tolerant training runtime.

Production posture on any mesh size:
  * auto-resume    — on start, restores the latest valid checkpoint
                     (params + optimizer + data step); a killed run
                     continues bit-exactly (tests/test_runtime.py proves it).
  * failure drill  — FailureInjector raises at a configured step to
                     exercise the restart path in tests/examples.
  * straggler watch— per-step wall times tracked; steps slower than
                     `straggler_factor` x running median are logged to the
                     metrics JSONL (on a real fleet this feeds re-slicing /
                     hot-spare swap; here it feeds the log so the policy is
                     testable).
  * elastic        — checkpoints are mesh-agnostic (gathered leaves +
                     logical resharding on restore), so a run checkpointed
                     on mesh A resumes on mesh B (test_checkpoint.py).
  * grad compression (optional int8 EF) for the cross-pod all-reduce.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import model as Mod
from repro.core.types import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as St
from repro.optim import adamw, compress


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    grad_compression: bool = False
    impl: str = "xla"
    fail_at_step: int = -1          # failure-injection drill (tests)
    metrics_path: Optional[str] = None


class FailureInjector:
    def __init__(self, fail_at: int):
        self.fail_at = fail_at

    def check(self, step: int):
        if self.fail_at >= 0 and step == self.fail_at:
            raise RuntimeError(f"injected failure at step {step}")


class StragglerWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        slow = (len(self.times) >= 5
                and dt > self.factor * float(np.median(self.times)))
        self.times.append(dt)
        if len(self.times) > 100:
            self.times.pop(0)
        if slow:
            self.flagged.append((step, dt))
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                 train_cfg: TrainConfig, data_cfg: DataConfig,
                 mesh=None, act_sharding=None):
        self.cfg, self.opt_cfg, self.tc = cfg, opt_cfg, train_cfg
        self.data = SyntheticLM(data_cfg)
        self.mesh = mesh
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.keep)
        self.watchdog = StragglerWatchdog(train_cfg.straggler_factor)
        self.injector = FailureInjector(train_cfg.fail_at_step)
        step_fn = St.make_train_step(
            cfg, opt_cfg, impl=train_cfg.impl, act_sharding=act_sharding,
            grad_compression=train_cfg.grad_compression)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self._metrics_f = (open(train_cfg.metrics_path, "a")
                           if train_cfg.metrics_path else None)

    # ------------------------------------------------------------ state ----
    def init_state(self):
        params = Mod.init_model(jax.random.PRNGKey(self.tc.seed), self.cfg)
        opt_state = adamw.init_opt_state(params)
        state: Dict[str, Any] = {"params": params, "opt": opt_state}
        if self.tc.grad_compression:
            state["residual"] = compress.init_residual(params)
        return state

    def resume_or_init(self):
        latest = self.ckpt.latest_step()
        state = self.init_state()
        if latest is None:
            return state, 0
        state = self.ckpt.restore(latest, like=state)
        print(f"[trainer] resumed from step {latest}")
        return state, latest

    # ------------------------------------------------------------- loop ----
    def train(self) -> Dict[str, Any]:
        state, start = self.resume_or_init()
        history = []
        for step in range(start, self.tc.total_steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.global_batch(step).items()}
            t0 = time.time()
            self.injector.check(step)
            if self.tc.grad_compression:
                (state["params"], state["opt"], metrics,
                 state["residual"]) = self.step_fn(
                    state["params"], state["opt"], batch, state["residual"])
            else:
                state["params"], state["opt"], metrics = self.step_fn(
                    state["params"], state["opt"], batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            slow = self.watchdog.record(step, dt)
            metrics.update(step=step, step_time_s=dt, straggler=bool(slow))
            history.append(metrics)
            if self._metrics_f:
                self._metrics_f.write(json.dumps(metrics) + "\n")
                self._metrics_f.flush()
            if step % self.tc.log_every == 0:
                print(f"[trainer] step {step} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms"
                      + (" STRAGGLER" if slow else ""))
            if (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.save(self.tc.total_steps, state, blocking=True)
        self.ckpt.wait()
        return {"state": state, "history": history,
                "stragglers": self.watchdog.flagged}
