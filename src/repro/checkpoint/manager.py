"""Fault-tolerant checkpointing: atomic, async, retention, mesh-elastic.

Layout (one directory per step):
    <root>/step_000100.tmp/...   (written)
    <root>/step_000100/          (atomic rename on completion)
        manifest.json            leaf paths, shapes, dtypes, tree structure
        arrays.npz               all leaves, flattened by manifest order

Guarantees:
  * atomicity  — readers never see partial checkpoints (tmp + rename; the
    manifest is written last inside the tmp dir).
  * restart    — `latest_step()` + `restore()`; corrupt/partial dirs are
    ignored (missing manifest) so a crash mid-save cannot poison resume.
  * elasticity — arrays are saved UNSHARDED by logical leaf (gathered), so
    restore can re-shard onto any mesh: restore(..., sharding=tree) places
    every leaf with jax.device_put against the *target* mesh's rules.
  * async      — save() returns immediately; a worker thread serializes.
    wait() joins (used before exit and in tests).
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

try:  # ml_dtypes provides bfloat16/float8 etc.; bundled with jax.
    import ml_dtypes
except ImportError:  # pragma: no cover
    ml_dtypes = None


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _resolve_dtype(name: str) -> np.dtype:
    """np.dtype(...) that also understands ml_dtypes names (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        pass
    if ml_dtypes is not None and hasattr(ml_dtypes, name):
        return np.dtype(getattr(ml_dtypes, name))
    raise ValueError(f"unknown dtype in checkpoint manifest: {name!r}")


def _to_portable(a: np.ndarray) -> np.ndarray:
    """npz round-trips only native numpy dtypes; ml_dtypes (bfloat16, fp8)
    come back as void. Ship those as raw bytes; manifest keeps the truth."""
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        return np.frombuffer(np.ascontiguousarray(a).tobytes(), dtype=np.uint8)
    return a


def _from_portable(a: np.ndarray, shape, dtype: np.dtype) -> np.ndarray:
    if a.dtype == np.uint8 and dtype != np.uint8:
        return np.frombuffer(a.tobytes(), dtype=dtype).reshape(shape)
    return np.asarray(a, dtype=dtype).reshape(shape)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Device->host transfer happens on the caller thread (cheap, and
        keeps the donated buffers coherent); file IO on the worker."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        if blocking:
            self._write(step, host, treedef)
        else:
            self._ensure_worker()
            self._q.put((step, host, treedef))

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step, host_leaves, treedef):
        final = self.root / f"step_{step:08d}"
        # unique tmp per writer: a blocking save and a queued async save of
        # the same step may run concurrently (e.g. final-step save); a shared
        # tmp dir races (one writer rmtree's it mid-write). The atomic
        # os.replace at the end makes last-wins safe.
        tmp = self.root / f"step_{step:08d}.tmp.{os.getpid()}.{id(host_leaves)}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{f"leaf_{i}": _to_portable(a)
                    for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        try:
            if final.exists():
                shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)
        except OSError:
            # a concurrent writer of the same step won the rename; its
            # payload is identical — drop ours
            shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        steps = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, sharding: Any = None) -> Any:
        """`like`: pytree with the target structure (shapes may be checked).
        `sharding`: optional matching pytree of Sharding — enables restoring
        onto a different mesh than the one that saved (elastic restart)."""
        d = self.root / f"step_{step:08d}"
        data = np.load(d / "arrays.npz")
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(like)
        assert manifest["num_leaves"] == len(leaves), "tree structure changed"
        out = []
        sh_leaves = (_flatten(sharding)[0] if sharding is not None
                     else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            saved_dtype = _resolve_dtype(manifest["dtypes"][i])
            saved_shape = tuple(manifest["shapes"][i])
            assert saved_shape == tuple(ref.shape), (
                f"leaf {i}: ckpt {saved_shape} vs model {ref.shape}")
            a = _from_portable(data[f"leaf_{i}"], saved_shape, saved_dtype)
            if a.dtype != ref.dtype:
                a = a.astype(ref.dtype)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, out)
