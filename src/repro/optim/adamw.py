"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Optimizer moments are fp32 and shard exactly like their parameters (ZeRO:
the sharding rules in distributed/sharding.py apply to the whole state
pytree). No optax dependency — the update is ~40 lines and keeping it
explicit lets the dry-run donate/shard the full state deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array     # () int32
    mu: Any             # fp32 pytree like params
    nu: Any             # fp32 pytree like params


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  *, decay_mask=None):
    """Returns (new_params, new_state, metrics). Params keep their dtype;
    math in fp32."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, decay):
        g = g.astype(jnp.float32) * clip
        mu = cfg.beta1 * mu + (1 - cfg.beta1) * g
        nu = cfg.beta2 * nu + (1 - cfg.beta2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_mask = treedef.flatten_up_to(decay_mask)
    out = [upd(p, g, m, n, d) for p, g, m, n, d in
           zip(flat_p, flat_g, flat_mu, flat_nu, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
