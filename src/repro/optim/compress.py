"""int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates step time
for small models. Quantizing gradients to int8 with per-tensor scale before
the reduce cuts DCN bytes 4x (vs fp32) / 2x (vs bf16); the quantization
residual is carried to the next step (error feedback), which keeps SGD-style
convergence (bounded bias — see tests/test_optim.py property test).

Usage: wrap value_and_grad output before apply_updates:
    grads_c, new_residual = compress_decompress(grads, residual)
Off by default; enabled by TrainConfig.grad_compression.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, residual) -> Tuple[Any, Any]:
    """Simulates the quantize -> all-reduce -> dequantize pipeline (the
    all-reduce itself is inserted by SPMD on the sharded grads; the dtype of
    the reduced tensor is what shrinks). Returns (effective grads, new
    residual)."""
    def leaf(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _quantize(x)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
