"""Pipeline parallelism: GPipe-style microbatching over the super-block seam.

The model is already `lax.scan`-over-super-blocks; pipeline parallelism
shards that leading super-block dim over a 'pipe' mesh axis and rotates
activations stage-to-stage with jax.lax.ppermute. The loop is written
forward-only — jax.grad transposes the ppermutes into the reverse pipeline,
so 1F1B-style backward scheduling falls out of autodiff rather than being
hand-scheduled.

shard_map runs in PARTIAL-MANUAL mode (axis_names={'pipe'}): the body is
explicit over the pipe axis but still SPMD-auto over data/model, so FSDP/TP/
SP sharding inside each stage keeps working unchanged — PP composes with the
rest of the mesh instead of replacing it.

Schedule (n stages, m microbatches, T = n + m - 1 ticks):
  tick t: stage 0 injects microbatch t (t < m); every stage applies its
  local super-blocks; outputs rotate +1; the last stage banks microbatch
  t-(n-1). Bubble fraction = (n-1)/T — reported by `bubble_fraction` and
  charged in the §Roofline pipeline analysis.

Numerical hygiene: stages compute on garbage during warmup/drain ticks (SPMD
runs the same program everywhere). Garbage is never *mixed into* results:
injection is a `where` on stage index, output banking is masked, the final
unembed sees zeros instead of drain garbage (zeros -> finite logits -> the
mask kills them; NaN would survive a `where`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import model as Mod
from repro.core.types import ModelConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int
    axis: str = "pipe"

    def __post_init__(self):
        assert self.num_microbatches >= self.num_stages, (
            "microbatches < stages leaves permanent bubbles")


def bubble_fraction(pcfg: PipelineConfig) -> float:
    n, m = pcfg.num_stages, pcfg.num_microbatches
    return (n - 1) / (n + m - 1)


def _stage_apply(blocks, cfg: ModelConfig, x, *, impl: str, remat: bool,
                 act_sharding=None):
    """One stage = this shard's super-blocks (leading dim already local)."""
    return Mod._stack_forward(blocks, cfg, x, cfg.layer_pattern,
                              impl=impl, remat=remat,
                              act_sharding=act_sharding)


def pipeline_loss_fn(params, cfg: ModelConfig, batch, pcfg: PipelineConfig,
                     *, stage_idx=None, impl: str = "xla",
                     remat: bool = True, aux_weight: float = 0.01,
                     act_sharding=None):
    """GPipe cross-entropy loss. Call inside shard_map (see make_* below).

    params['blocks'] leaves carry the LOCAL stage's super-blocks on dim 0;
    everything else (embed, head, norms) is pipe-replicated. batch tensors
    are pipe-replicated; only stage 0 reads them. stage_idx: (1,) int32 —
    the stage id travels AS DATA (pipe-sharded iota) because
    lax.axis_index under partial-manual shard_map lowers to a PartitionId
    op the SPMD partitioner rejects (same workaround as context_parallel)."""
    from repro.core.compat import axis_size
    n = axis_size(pcfg.axis)
    stage = (jax.lax.axis_index(pcfg.axis) if stage_idx is None
             else stage_idx[0])
    m = pcfg.num_microbatches
    tokens, labels = batch["tokens"], batch["labels"]
    bsz, seq = tokens.shape
    assert bsz % m == 0, (bsz, m)
    mb = bsz // m
    tok_mb = tokens.reshape(m, mb, seq)

    def constrain(x):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(x, act_sharding)
        return x

    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    buf = jnp.zeros((mb, seq, cfg.d_model), dt)
    outs = jnp.zeros((m, mb, seq, cfg.d_model), dt)
    aux_total = jnp.zeros((), jnp.float32)

    for t in range(m + n - 1):
        # stage 0 embeds & injects microbatch t; later stages use the buffer
        inj = Mod.embed_tokens(params, cfg, {"tokens": tok_mb[min(t, m - 1)]})
        x = jnp.where(stage == 0, inj.astype(dt), buf)
        x, aux = _stage_apply(params["blocks"], cfg, constrain(x), impl=impl,
                              remat=remat, act_sharding=act_sharding)
        # this stage works on microbatch t - stage; mask warmup/drain aux
        live = jnp.logical_and(t >= stage, t - stage < m)
        aux_total = aux_total + jnp.where(live, aux, 0.0)
        out_idx = t - (n - 1)
        if out_idx >= 0:
            keep = jnp.where(stage == n - 1, 1.0, 0.0).astype(x.dtype)
            outs = outs.at[out_idx].set(x * keep)
        if t < m + n - 2:
            buf = jax.lax.ppermute(
                x, pcfg.axis, [(i, i + 1) for i in range(n - 1)])

    # unembed + CE on the banked outputs (zeros on non-final stages -> finite
    # logits, masked below). Same TP-safe CE as Mod.loss_fn.
    x = outs.reshape(bsz, seq, cfg.d_model)
    logits = Mod._unembed(params, cfg, x)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = labels.reshape(m, mb, seq).reshape(bsz, seq)[:, 1:]
    valid = targets >= 0
    tsafe = jnp.where(valid, targets, 0)
    mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    z = logits - mx
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    hit = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
           == tsafe[..., None])
    picked = jnp.sum(jnp.where(hit, z, 0.0), axis=-1)
    nll = jnp.where(valid, lse - picked, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = jnp.sum(nll) / denom
    is_last = jnp.where(stage == n - 1, 1.0, 0.0)
    loss = jax.lax.psum(ce * is_last, pcfg.axis)
    # aux is a per-token mean statistic: average over microbatches so PP
    # matches the single-pass loss (which sees the full batch once)
    aux_all = jax.lax.psum(aux_total, pcfg.axis) / m
    total = loss + aux_weight * aux_all
    return total, {"loss": loss, "aux_loss": aux_all,
                   "tokens": denom.astype(jnp.float32)}


def make_pipeline_loss(cfg: ModelConfig, pcfg: PipelineConfig, mesh: Mesh,
                       *, impl: str = "xla", remat: bool = True,
                       act_sharding=None):
    """shard_map-wrapped loss(params, batch) -> (loss, metrics).

    Partial-manual over the pipe axis only: params['blocks'] dim 0 is
    pipe-sharded, all else pipe-replicated; data/model axes stay auto so the
    in-stage FSDP/TP/SP sharding tables apply unchanged."""
    assert cfg.num_super_blocks % pcfg.num_stages == 0, (
        f"{cfg.num_super_blocks} super-blocks not divisible into "
        f"{pcfg.num_stages} stages")

    body = functools.partial(pipeline_loss_fn, cfg=cfg, pcfg=pcfg, impl=impl,
                             remat=remat, act_sharding=act_sharding)

    def loss(params, batch):
        in_specs = (
            {k: (jax.tree.map(lambda _: P(pcfg.axis), v)
                 if k == "blocks" else jax.tree.map(lambda _: P(), v))
             for k, v in params.items()},
            jax.tree.map(lambda _: P(), batch),
            P(pcfg.axis),
        )
        from repro.core.compat import shard_map
        # partial-manual (pipe manual, data/model auto) is the intent; the
        # legacy XLA SPMD partitioner rejects partial-manual programs
        # (IsManualSubgroup check), so on old jax run fully manual — the
        # other axes just replicate this loss, which only uses `pipe`.
        manual = ({pcfg.axis} if hasattr(jax, "shard_map")
                  else set(mesh.axis_names))
        fn = shard_map(
            lambda p, b, s: body(p, batch=b, stage_idx=s),
            mesh=mesh, in_specs=in_specs,
            out_specs=(P(), {"loss": P(), "aux_loss": P(), "tokens": P()}),
            axis_names=manual, check_vma=False)
        # stage index as pipe-sharded data (see pipeline_loss_fn docstring)
        return fn(params, batch,
                  jnp.arange(pcfg.num_stages, dtype=jnp.int32))

    return loss


def make_pp_train_step(cfg: ModelConfig, opt_cfg, pcfg: PipelineConfig,
                       mesh: Mesh, *, impl: str = "xla",
                       act_sharding=None):
    """fwd + (autodiff-transposed) reverse pipeline + AdamW."""
    from repro.optim import adamw
    loss = make_pipeline_loss(cfg, pcfg, mesh, impl=impl,
                              act_sharding=act_sharding)

    def train_step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        new_params, new_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        return new_params, new_state, {**metrics, **om}

    return train_step
