"""Context parallelism for window attention: halo exchange, not all-gather.

This is the paper's central dataflow insight lifted from the FPGA fabric to
the pod fabric. SWAT's FIFO K/V buffer exists because the band makes each
row's working set *local*: row i needs only kv rows [i-w, i+w]. Across
devices the same locality means a sequence-sharded device needs only a
w-token *halo* from its neighbour(s) — O(w·D) wire bytes per device instead
of the O(L·D) all-gather that dense attention forces. Collective traffic
becomes independent of sequence length: the cross-device FIFO.

Mechanics (inside shard_map over `axis`, n shards, local length Lp):
  * left halo   : ceil(w/Lp) hops of jax.lax.ppermute shift the left
                  neighbour's shard(s) in; devices that receive nothing
                  (the left edge) get zeros, masked out by kv bounds.
  * right halo  : same, shifted the other way (bidirectional specs only).
  * band pass   : the exact-band kernel runs on [halo | local | halo] with a
                  constant local shift (band masks are shift-invariant); the
                  per-shard valid kv range [kv_lo, kv_hi) — traced scalars —
                  masks the sequence edges.
  * global cols : the first g kv rows (shard 0) are psum-broadcast (g is
                  static and small); every local row folds them in with a
                  local logsumexp merge. Columns already inside the row's
                  band are excluded (the single-device kernel dedupes these
                  via its slot pattern).
  * global rows : q rows < g attend everything, so each shard computes its
                  local partial and a pmax/psum logsumexp merge combines
                  them — one (g, D)-sized collective, not a kv gather.

Random (BigBird) blocks are NOT supported under context parallelism: a
random column set defeats the locality that makes the halo cheap (it would
need an all-to-all). Use data/tensor parallelism for BigBird-style specs, or
re-draw random blocks shard-locally (config opt-in) — see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels import dots
from repro.kernels import ops as kops

NEG_INF = kops.NEG_INF


def halo_hops(window: int, local_len: int) -> int:
    """ppermute hops needed to cover a w-token halo with Lp-token shards."""
    return -(-window // local_len)


def halo_rows(window: int, local_len: int, block: int = 128) -> int:
    """Rows actually wired per side. When the window fits inside one shard
    only the (block-aligned) w-row tail travels — this is what makes the
    halo O(w), independent of L. Multi-hop (w > Lp) ships whole shards,
    bounded by < w + Lp < 2w."""
    if window <= local_len:
        return min(local_len, -(-window // block) * block)
    return halo_hops(window, local_len) * local_len


def _shift_in(x, axis: str, hops: int, direction: int, rows: int):
    """Collect the `rows`-deep halo along the sequence dim (dim 2).

    direction=+1: left halo (device i receives from i-1, ..., i-hops);
    direction=-1: right halo. Non-receiving edge devices get zeros (masked
    by kv bounds downstream). Returns the concatenation in sequence order.
    """
    from repro.core.compat import axis_size
    n = axis_size(axis)
    lp = x.shape[2]
    if hops == 0 or n == 1:
        return x[:, :, :0]
    if direction > 0:
        perm = [(i, i + 1) for i in range(n - 1)]
    else:
        perm = [(i + 1, i) for i in range(n - 1)]
    if hops == 1 and rows < lp:
        # single hop: wire only the facing `rows`-deep edge of the shard
        edge = x[:, :, -rows:] if direction > 0 else x[:, :, :rows]
        return jax.lax.ppermute(edge, axis, perm)
    out = []
    cur = x
    for _ in range(hops):
        cur = jax.lax.ppermute(cur, axis, perm)
        if direction > 0:
            out.insert(0, cur)   # furthest shard first
        else:
            out.append(cur)
    return jnp.concatenate(out, axis=2)


def _merge(p1: Tuple, p2: Tuple) -> Tuple:
    """Logsumexp-merge two flash partials (acc, l, m). acc unnormalized
    fp32 (…, D); l, m (…,) fp32. NEG_INF m marks an empty partial."""
    acc1, l1, m1 = p1
    acc2, l2, m2 = p2
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)   # exp(-1e30 - m) underflows to exactly 0
    a2 = jnp.exp(m2 - m)
    return (acc1 * a1[..., None] + acc2 * a2[..., None],
            l1 * a1 + l2 * a2, m)


def _finalize(p: Tuple, dtype) -> jax.Array:
    acc, l, _ = p
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def _dense_partial(q, k, v, scale, softcap, mask):
    """Flash partial of a small dense pass. q: (B,Hq,Lq,D); k/v (B,Hkv,S,D);
    mask broadcastable to (B,Hq,Lq,S)."""
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qb = q.reshape(b, hkv, group, lq, d)
    s = dots.einsum_f32("bhgqd,bhkd->bhgqk",
                        qb * jnp.asarray(scale, q.dtype), k)
    s = s.reshape(b, hq, lq, k.shape[2])
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1))
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    pb = p.reshape(b, hkv, group, lq, -1)
    acc = dots.einsum_f32("bhgqk,bhkd->bhgqd", pb.astype(v.dtype), v)
    acc = acc.astype(jnp.float32).reshape(b, hq, lq, d)
    return acc, jnp.sum(p, -1), m


def swat_attention_cp_local(q, k, v, idx_arr=None, *, spec: AttentionSpec,
                            axis: str, seq_len: int,
                            block_q: int = 128, block_kv: int = 128,
                            scale: Optional[float] = None):
    """The per-shard body (call inside shard_map over `axis`).

    q, k, v: LOCAL shards (B, H, Lp, D) of a (B, H, L, D) problem with the
    sequence dim sharded over `axis`. Returns the local output shard.

    idx_arr: optional (1,) int32 carrying this shard's index (an arange
    sharded over `axis`). When given it replaces `lax.axis_index` — at depth
    (tens of identical manual regions) XLA CSE hoists the partition-id
    instruction out of the manual subgraphs and the auto partitioner rejects
    it ("PartitionId ... ambiguous"); a sharded input is just data and
    cannot be hoisted wrong (§Perf cell 2 follow-up).
    """
    assert spec.is_sparse and spec.window > 0, "CP needs a window spec"
    assert spec.num_random == 0, "random blocks break halo locality (DESIGN.md)"
    b, hq, lp, d = q.shape
    hkv = k.shape[1]
    scale = float(d ** -0.5 if scale is None else scale)
    from repro.core.compat import axis_size
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis) if idx_arr is None else idx_arr[0]
    assert lp * n == seq_len, (lp, n, seq_len)
    w, g = spec.window, spec.num_global

    hops_l = halo_hops(w, lp)
    hops_r = 0 if spec.causal else hops_l
    halo = halo_rows(w, lp, block_kv)
    halo_r = 0 if spec.causal else halo

    k_ext = jnp.concatenate(
        [_shift_in(k, axis, hops_l, +1, halo), k,
         _shift_in(k, axis, hops_r, -1, halo_r)], axis=2)
    v_ext = jnp.concatenate(
        [_shift_in(v, axis, hops_l, +1, halo), v,
         _shift_in(v, axis, hops_r, -1, halo_r)], axis=2)

    # valid local kv range: global index k_loc - halo + idx*Lp in [0, L)
    kv_lo = jnp.maximum(0, halo - idx * lp)
    kv_hi = jnp.minimum(k_ext.shape[2], seq_len - idx * lp + halo)

    band_spec = dataclasses.replace(spec, num_global=0, num_random=0)
    pattern = patterns.build_block_pattern(
        band_spec, lp, k_ext.shape[2], block_q, block_kv, q_shift=halo)
    part = kops._xla_banded(q, k_ext, v_ext, band_spec, pattern, scale,
                            q_shift=halo, kv_lo=kv_lo, kv_hi=kv_hi,
                            return_partials=True)

    q_global_idx = idx * lp + jnp.arange(lp)          # (Lp,)

    def _bcast0(x):
        """psum-broadcast shard 0's slice. fp32 on the wire: bf16 psum under
        partial-manual shard_map hits an XLA CPU partitioner bug ("Invalid
        binary instruction opcode copy"); fp32 lowers cleanly and the halo
        is tiny so the 2x wire cost is noise."""
        own32 = jnp.where(idx == 0, 1.0, 0.0)
        return jax.lax.psum(x.astype(jnp.float32) * own32, axis).astype(
            x.dtype)

    if g:
        gl = min(g, lp)
        assert gl == g, f"num_global={g} must fit one shard (Lp={lp})"
        # ---- global COLUMNS: broadcast shard 0's first g kv rows ----
        kg = _bcast0(k[:, :, :g])
        vg = _bcast0(v[:, :, :g])
        kcol = jnp.arange(g)[None, None, None, :]
        qrow = q_global_idx[None, None, :, None]
        colmask = kcol < qrow - w          # dedupe: band pass already covers
        if spec.causal:                    # [q-w, q]; globals add only k<q-w
            colmask &= kcol <= qrow
        part = _merge(part, _dense_partial(q, kg, vg, scale, spec.softcap,
                                           colmask))

    out = _finalize(part, q.dtype)

    if g:
        # ---- global ROWS: first g q rows (shard 0's, psum-broadcast) attend
        # ALL kv; every shard contributes its local partial, merged with
        # pmax/psum ----
        qg = _bcast0(q[:, :, :g])
        krow = (idx * lp + jnp.arange(lp))[None, None, None, :]
        growmask = jnp.broadcast_to(krow < seq_len, (1, 1, g, lp))
        if spec.causal:
            growmask = krow <= jnp.arange(g)[None, None, :, None]
        acc, l, m = _dense_partial(qg, k, v, scale, spec.softcap, growmask)
        m_star = jax.lax.pmax(m, axis)
        a = jnp.exp(m - m_star)
        acc = jax.lax.psum(acc * a[..., None], axis)
        l = jax.lax.psum(l * a, axis)
        g_out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        # replace rows with global q index < g (only shard 0 has any)
        is_global = (q_global_idx < g)[None, None, :, None]
        g_pad = jnp.pad(g_out, ((0, 0), (0, 0), (0, lp - g), (0, 0)))
        out = jnp.where(is_global, g_pad, out)
    return out


def swat_attention_context_parallel(
        q, k, v, spec: AttentionSpec, *, mesh: Mesh, axis: str = "model",
        block_q: int = 128, block_kv: int = 128,
        scale: Optional[float] = None):
    """Sequence-sharded window attention over `axis` of `mesh`.

    q, k, v: (B, H, L, D) global arrays; the op shards L over `axis`,
    exchanges w-token halos, and returns the (B, H, L, D) output with the
    same sharding. shard_map runs PARTIAL-MANUAL over `axis` only, so batch/
    head dims stay SPMD-auto (compose with DP on other mesh axes).
    Differentiable (shard_map transposes the ppermutes)."""
    n = mesh.shape[axis]
    lq = q.shape[2]
    assert lq % n == 0, f"seq {lq} must divide over {axis}={n}"
    io_spec = P(None, None, axis, None)
    body = functools.partial(
        swat_attention_cp_local, spec=spec, axis=axis, seq_len=lq,
        block_q=block_q, block_kv=block_kv, scale=scale)
    from repro.core.compat import shard_map
    fn = shard_map(body, mesh=mesh,
                   in_specs=(io_spec,) * 3 + (P(axis),),
                   out_specs=io_spec, axis_names={axis},
                   check_vma=False)
    # shard index travels as data (see swat_attention_cp_local docstring)
    return fn(q, k, v, jnp.arange(n, dtype=jnp.int32))


def cp_wire_bytes_per_device(seq_len: int, n_shards: int, window: int,
                             num_heads: int, head_dim: int,
                             bytes_per_el: int = 2, batch: int = 1,
                             causal: bool = True) -> int:
    """Analytic halo traffic (per device, per layer): the roofline model the
    dry-run numbers are checked against. K and V, halo_rows each way —
    O(w), independent of seq_len once the window fits one shard."""
    lp = seq_len // n_shards
    rows = halo_rows(window, lp)
    sides = 1 if causal else 2
    return 2 * sides * rows * num_heads * head_dim * bytes_per_el * batch
