"""Sharding rules: FSDP + TP + EP + SP with divisibility fallback.

Every parameter / cache / batch leaf gets an ordered list of
(dim, axes-preference) rules by *role* (derived from its pytree path). Rules
are applied greedily: an axis is used only if the dim is divisible by it and
the axis is not already used by an earlier rule on the same leaf — otherwise
the next preference (or replication) applies. This is what lets one rule
table drive vocab sizes like 50280 (not 16-divisible -> falls back), kv_heads
8 < model=16 (falls back to sharding the cache's sequence dim => flash-decode
style sequence-split), and batch=1 long-context decode (shards the KV
sequence axis instead of batch).

Conventions:
  params:  TP on the contraction-adjacent dim over 'model'
           (column-parallel in-proj, row-parallel out-proj),
           FSDP over 'data' on another dim, experts over 'model' (EP).
  batch:   leading dim over ('pod','data').
  caches:  batch -> ('pod','data'), heads -> 'model', else seq -> 'model'.
  activations (training): batch -> ('pod','data'), sequence -> 'model' (SP)
           at super-block boundaries (layers.with_activation_constraint).
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on path, [(dim, (axes preference tuples...)), ...])
# dim indices are for the UNSTACKED leaf; stacked block leaves (leading
# super-block dim) are shifted automatically.
_PARAM_RULES: List[Tuple[str, List[Tuple[int, Sequence[Any]]]]] = [
    # order matters: specific (moe/...) before generic — first match wins
    (r"moe/(w1|w3)$",         [(0, ("model",)), (2, ("data",))]),
    (r"moe/w2$",              [(0, ("model",)), (1, ("data",))]),
    (r"embed$",               [(0, ("model", "data")), (1, ("data",))]),
    (r"lm_head$",             [(1, ("model", "data")), (0, ("data",))]),
    (r"(wq|wk|wv|w1|w3)$",    [(1, ("model",)), (0, ("data",))]),
    (r"(wo|w2|out_proj)$",    [(0, ("model",)), (1, ("data",))]),
    (r"in_proj$",             [(1, ("model",)), (0, ("data",))]),
    (r"router$",              [(0, ("data",))]),
    (r"conv_w$",              [(1, ("model",))]),
    (r"(bq|bk|bv|conv_b)$",   [(0, ("model",))]),
    # norms, a_log, d_skip, dt_bias, scalars: replicated (no rule)
]

_CACHE_RULES: List[Tuple[str, List[Tuple[int, Sequence[Any]]]]] = [
    (r"(^|/)(k|v|xk|xv)$", [(0, (("pod", "data"), "data")),
                            (1, ("model",)),
                            (2, ("model", "data", ("model", "data")))]),
    # paged pool (local-id mode): (slots, nb+1, H, page, D) — slot dim rides
    # the batch axes, heads ride 'model'; page rows must never split
    (r"(^|/)(pk|pv)$",     [(0, (("pod", "data"), "data")),
                            (2, ("model",))]),
    (r"(^|/)table$",       [(0, (("pod", "data"), "data"))]),
    (r"conv$",             [(0, (("pod", "data"), "data")),
                            (2, ("model",))]),
    (r"ssm$",              [(0, (("pod", "data"), "data")),
                            (1, ("model",)),
                            (3, ("model",))]),
    # per-slot ring write pointer: MUST ride the same slot axis as the K/V
    # batch dim — a replicated `step` under a slot-sharded cache makes every
    # ring insert a cross-shard broadcast and desyncs the per-slot rotation
    (r"(^|/)step$",        [(0, (("pod", "data"), "data"))]),
]

# MoE sharded over 'model': expert dim of the dispatch buffers
_EXPERT_RULE = [(0, ("model",))]

# ---- "cp" profile: no TP — both mesh axes do FSDP (ZeRO-3 2D), compute is
# sequence-sharded everywhere and window attention runs halo-exchange context
# parallelism (kernels/ops.set_context_parallel). Weights are gathered on
# use (prefetch overlaps under async all-gather) instead of being
# matmul-partitioned; activations never all-gather. MoE keeps EP.
_PARAM_RULES_CP: List[Tuple[str, List[Tuple[int, Sequence[Any]]]]] = [
    (r"moe/(w1|w3|w2)$",      [(0, ("model",)), (1, ("data",))]),
    (r"embed$",               [(0, (("data", "model"), "data", "model")),
                               (1, ("data",))]),
    (r"lm_head$",             [(1, (("data", "model"), "data", "model")),
                               (0, ("data",))]),
    (r"(wq|wk|wv|w1|w3|wo|w2|out_proj|in_proj)$",
                              [(0, (("data", "model"), "data")),
                               (1, (("data", "model"), "data"))]),
    (r"router$",              [(0, ("data",))]),
    (r"conv_w$",              [(1, (("data", "model"), "data"))]),
    (r"(bq|bk|bv|conv_b)$",   [(0, (("data", "model"), "data"))]),
]

# "fsdp": same 2D-FSDP parameter placement as "cp" but compute stays
# batch-parallel (no CP attention, activations batch-sharded over BOTH mesh
# axes). The right profile for small-model big-batch training cells where
# Megatron TP+SP is pure collective overhead (§Perf cell 3).
_PROFILES = {"tp": None, "cp": _PARAM_RULES_CP, "fsdp": _PARAM_RULES_CP}


def _path_str(path) -> str:
    parts = []
    for pk in path:
        if isinstance(pk, jax.tree_util.DictKey):
            parts.append(str(pk.key))
        elif isinstance(pk, jax.tree_util.GetAttrKey):
            parts.append(pk.name)
        else:
            parts.append(str(pk))
    return "/".join(parts)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _spec_for(shape: Tuple[int, ...], rules, mesh: Mesh,
              shift: int = 0) -> P:
    assignment: List[Optional[Any]] = [None] * len(shape)
    used: set = set()
    for dim, prefs in rules:
        d = dim + shift
        if d >= len(shape):
            continue
        for axes in prefs:
            names = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(n not in mesh.axis_names for n in names):
                continue
            if any(n in used for n in names):
                continue
            if shape[d] % _axis_size(mesh, names) != 0:
                continue
            assignment[d] = axes if isinstance(axes, str) else tuple(axes)
            used.update(names)
            break
    return P(*assignment)


def _match_rules(path: str, tables) -> Optional[List]:
    for pattern, rules in tables:
        if re.search(pattern, path):
            return rules
    return None


def param_sharding(shapes, mesh: Mesh, profile: str = "tp"):
    """shapes: pytree of ShapeDtypeStruct (from jax.eval_shape(init_model)).
    Returns matching pytree of NamedSharding. profile: 'tp' (Megatron
    TP+FSDP, default) or 'cp' (2D-FSDP, for context-parallel compute)."""
    tables = _PROFILES.get(profile) or _PARAM_RULES

    def leaf(path, x):
        p = _path_str(path)
        shift = 1 if re.match(r"(blocks|enc_blocks)(/|$)", p) else 0
        rules = _match_rules(p, tables)
        spec = _spec_for(x.shape, rules, mesh, shift) if rules else P()
        if (shift and "pipe" in mesh.axis_names
                and x.shape[0] % mesh.shape["pipe"] == 0):
            # pipeline meshes shard the stacked super-block dim over stages
            # (rules are shifted, so dim 0 is always free here)
            assn = list(spec) + [None] * (len(x.shape) - len(spec))
            assn[0] = "pipe"
            spec = P(*assn)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, shapes)


def _strip_batch_axes(spec: P) -> P:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in ("data", "pod"))
        out.append(kept[0] if len(kept) == 1 else (kept or None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_sharding_serving(shapes, mesh: Mesh, profile: str = "tp"):
    """Inference parameter placement: TP over 'model' only — the batch
    axes ('data'/'pod') REPLICATE the weights instead of FSDP-sharding
    them. Training's data-axis shard is a memory optimization paid for
    with an all-gather per use; inside the decode scan that puts a weight
    gather (or a row-parallel partial-sum all-reduce) in every step of the
    hot path, which breaks the slot-parallel collective-free contract the
    static analyzer enforces."""
    tree = param_sharding(shapes, mesh, profile)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _strip_batch_axes(s.spec)), tree)


def cache_sharding(shapes, mesh: Mesh):
    """Caches are stacked over super-blocks (leading dim) — shift always 1."""
    def leaf(path, x):
        p = _path_str(path)
        rules = _match_rules(p, _CACHE_RULES)
        spec = _spec_for(x.shape, rules, mesh, shift=1) if rules else P()
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, shapes)


def batch_sharding(shapes, mesh: Mesh, profile: str = "tp"):
    """Leading dim over ('pod','data') when divisible, else replicate.
    fsdp profile: over ('pod','data','model') — one sequence per chip."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def leaf(x):
        if profile == "fsdp":
            rules = [(0, (baxes + ("model",), baxes, "data"))]
        else:
            rules = [(0, (baxes, "data"))]
        return NamedSharding(mesh, _spec_for(x.shape, rules, mesh))
    return jax.tree_util.tree_map(leaf, shapes)


def activation_spec(mesh: Mesh, sequence_parallel: bool = True,
                    profile: str = "tp") -> P:
    """(B, L, D) activations at super-block boundaries.

    tp/cp : batch over ('pod','data'); sequence over 'model' (Megatron SP /
            the layout context-parallel attention consumes directly).
    fsdp  : batch over ('pod','data','model') — every chip holds whole
            sequences; no sequence collectives at all."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if profile == "fsdp":
        return P(baxes + ("model",), None, None)
    return P(baxes, "model" if sequence_parallel else None, None)


def decode_batch_sharding(shapes, mesh: Mesh, slots: int,
                          slot_dim: int = 0):
    """Serving decode-state shardings: any leaf whose `slot_dim` equals the
    engine's slot count rides the slot axis over ('pod','data') (divisibility
    permitting); everything else (RNG keys, scalars) replicates. This is the
    batch analogue of cache_sharding for the per-slot host vectors the
    engine threads through `_Compiled` — `step`/`slot_last`/`slot_budget`/
    `slot_temp`/`active` — and for (slots, ...) token/logit blocks.

    slot_dim is EXPLICIT (no shape sniffing): the scan's stacked per-step
    outputs are (T, slots) and pass slot_dim=1 — when T happens to equal
    the slot count, guessing the dim would shard the time axis and force a
    cross-device relayout of every decode block's output."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rules = [(0, (baxes, "data"))]

    def leaf(x):
        shape = tuple(x.shape)
        if len(shape) > slot_dim and shape[slot_dim] == slots:
            return NamedSharding(mesh,
                                 _spec_for(shape, rules, mesh,
                                           shift=slot_dim))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(leaf, shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
