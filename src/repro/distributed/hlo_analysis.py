"""Post-compile HLO analysis: collective bytes + the three roofline terms.

cost_analysis() gives FLOPs and HBM bytes but no collective traffic, so we
parse the optimized (SPMD-partitioned, per-device) HLO text and sum the
result-shape bytes of every collective op. Shapes in the partitioned module
are already per-device, so the terms below are per-chip seconds directly.

Per-op byte factors (ring-algorithm wire bytes per participating chip,
(n-1)/n ~ 1 at n=16..512):
  all-reduce        2x result   (reduce-scatter + all-gather phases)
  all-gather        1x result
  reduce-scatter    1x operand  (= result * n; we use result * shards)
  all-to-all        1x result
  collective-permute 1x result
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]   # raw result bytes (per device)
    wire_bytes: float               # factor-weighted bytes (per device)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Count each collective ONCE and charge its RESULT bytes once.

    Async collectives lower to a `-start` / `-done` pair. Only the start is
    counted (the done is the same transfer completing — counting both would
    double every async collective), and a start's printed result is the
    tuple `(operand-alias, result)`, so summing the whole tuple used to
    double its bytes too: only the final tuple element (the actual result
    buffer) is charged, which makes async and sync lowerings of the same op
    cost the same wire bytes."""
    counts: Dict[str, int] = {}
    by_kind: Dict[str, int] = {}
    wire = 0.0
    factors = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        if suffix == "-start" and shape_str.startswith("("):
            shapes = _SHAPE_RE.findall(shape_str)
            if shapes:
                dtype, dims = shapes[-1]
                shape_str = f"{dtype}[{dims}]"
        b = _shape_bytes(shape_str)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + b
        wire += factors[kind] * b
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind,
                           wire_bytes=wire)


# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (we charge one link, worst-case)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                # per device
    bytes_accessed: float       # per device
    collective_bytes: float     # per device, factor-weighted
    model_flops: float          # 6ND / 2ND (per device share)
    counts: Dict[str, int]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achieved if the chip
        runs at the dominant-term rate: (useful compute time) / (bound)."""
        ideal = self.model_flops / PEAK_FLOPS_BF16
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops_per_device": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_counts": self.counts,
        }


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Per-entry-point collective budget for the static analyzer.

    allow:           max instruction count per collective kind. An empty
                     dict is the COLLECTIVE-FREE budget — the contract for
                     slot-parallel decode, which must stay embarrassingly
                     parallel over slots.
    max_wire_bytes:  ceiling on factor-weighted wire bytes per device
                     (0 = no byte ceiling, counts only). TP entry points
                     declare measured bytes plus headroom so an XLA-version
                     wobble passes but a new collective does not.
    """
    allow: Tuple[Tuple[str, int], ...] = ()
    max_wire_bytes: float = 0.0

    @classmethod
    def collective_free(cls) -> "CollectiveBudget":
        return cls(allow=(), max_wire_bytes=0.0)

    @classmethod
    def from_counts(cls, counts: Dict[str, int],
                    wire_bytes: float, headroom: float = 1.5
                    ) -> "CollectiveBudget":
        """Bless a measured profile as the budget (with byte headroom)."""
        return cls(allow=tuple(sorted(counts.items())),
                   max_wire_bytes=float(wire_bytes) * headroom)

    def to_dict(self) -> dict:
        return {"allow": dict(self.allow),
                "max_wire_bytes": self.max_wire_bytes}


def check_budget(stats: CollectiveStats,
                 budget: CollectiveBudget) -> List[str]:
    """Budget violations for one entry point's compiled module ([] = ok)."""
    out: List[str] = []
    allow = dict(budget.allow)
    for kind, n in sorted(stats.counts.items()):
        cap = allow.get(kind, 0)
        if n > cap:
            what = ("collective-free entry emits" if not allow
                    else f"budget allows {cap}, compiled module has")
            out.append(f"{kind}: {what} {n} instruction(s)")
    if budget.max_wire_bytes and stats.wire_bytes > budget.max_wire_bytes:
        out.append(f"wire bytes {stats.wire_bytes:.0f} exceed budget "
                   f"{budget.max_wire_bytes:.0f}")
    return out


def roofline_terms(cost: dict, coll: CollectiveStats,
                   model_flops_per_device: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=coll.wire_bytes / ICI_BW,
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=coll.wire_bytes,
        model_flops=model_flops_per_device,
        counts=coll.counts,
    )
