"""Step functions: the units the dry-run lowers and the trainers run.

  train_step  : fwd + bwd + AdamW update (+ optional int8 EF compression)
  prefill_step: prompt -> (last logits, primed caches)
  serve_step  : one decode token against the caches
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import model as Mod
from repro.core.types import ModelConfig
from repro.optim import adamw, compress


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, *,
                    impl: str = "xla", act_sharding=None,
                    grad_compression: bool = False,
                    donate: bool = True, unroll: bool = False,
                    remat_policy: str = "nothing",
                    remat: bool = True) -> Callable:
    def train_step(params, opt_state, batch, residual=None):
        (loss, metrics), grads = jax.value_and_grad(
            Mod.loss_fn, has_aux=True)(params, cfg, batch, impl=impl,
                                       act_sharding=act_sharding,
                                       unroll=unroll, remat=remat,
                                       remat_policy=remat_policy)
        if grad_compression:
            grads, residual = compress.compress_decompress(grads, residual)
        new_params, new_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om}
        if grad_compression:
            return new_params, new_state, metrics, residual
        return new_params, new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *,
                      impl: str = "xla", unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        return Mod.prefill(params, cfg, batch, max_len=max_len, impl=impl,
                           unroll=unroll)
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, impl: str = "xla",
                    unroll: bool = False) -> Callable:
    def serve_step(params, caches, batch):
        return Mod.decode_step(params, cfg, batch, caches, impl=impl,
                               unroll=unroll)
    return serve_step


def make_eval_step(cfg: ModelConfig, *, impl: str = "xla") -> Callable:
    def eval_step(params, batch):
        loss, metrics = Mod.loss_fn(params, cfg, batch, impl=impl,
                                    remat=False)
        return metrics
    return eval_step
