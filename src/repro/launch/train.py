"""Production training launcher: mesh-sharded pjit training with the full
fault-tolerance stack. On a real TPU fleet this is the per-host entry point
(jax.distributed.initialize + the same mesh); on CPU it runs the identical
code path on a debug mesh (--debug-mesh, subprocess-safe with
--device-count).

    # real pod (per host):
    python -m repro.launch.train --arch llama3.2-1b --steps 1000

    # CPU rehearsal on a 2x2 fake mesh:
    python -m repro.launch.train --arch llama3.2-1b --smoke \
        --device-count 4 --debug-mesh 2,2 --steps 4
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU rehearsal)")
    ap.add_argument("--device-count", type=int, default=0,
                    help="force host platform device count (set BEFORE jax)")
    ap.add_argument("--debug-mesh", default="",
                    help="e.g. 2,2 -> (data, model) debug mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1)
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.distributed import sharding as Sh
    from repro.launch import mesh as mesh_lib
    from repro.launch import steps as St
    from repro.core import model as Mod
    from repro.optim import adamw
    from repro.checkpoint.manager import CheckpointManager

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.debug_mesh:
        dims = tuple(int(x) for x in args.debug_mesh.split(","))
        mesh = mesh_lib.make_debug_mesh(*dims)
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    opt_cfg = adamw.AdamWConfig(total_steps=args.steps, warmup_steps=10)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    with mesh:
        p_specs = jax.eval_shape(
            lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
        p_shard = Sh.param_sharding(p_specs, mesh)
        o_shard = adamw.OptState(step=Sh.replicated(mesh), mu=p_shard,
                                 nu=p_shard)
        act = jax.sharding.NamedSharding(mesh, Sh.activation_spec(
            mesh, sequence_parallel=args.seq % mesh.shape["model"] == 0))
        step_fn = jax.jit(
            St.make_train_step(cfg, opt_cfg, act_sharding=act,
                               grad_compression=args.grad_compression),
            in_shardings=(p_shard, o_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1))

        params = jax.jit(lambda: Mod.init_model(jax.random.PRNGKey(0), cfg),
                         out_shardings=p_shard)()
        opt_state = jax.jit(adamw.init_opt_state,
                            out_shardings=o_shard)(params)

        start = ckpt.latest_step() or 0
        if start:
            state = ckpt.restore(start, like={"params": params,
                                              "opt": opt_state},
                                 sharding={"params": p_shard,
                                           "opt": o_shard})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed at step {start}")

        for step in range(start, args.steps):
            if step == args.fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = {k: jnp.asarray(v)
                     for k, v in data.global_batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"[train] step {step} "
                      f"loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if (step + 1) % 50 == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  blocking=True)
        ckpt.wait()
        print("[train] done")


if __name__ == "__main__":
    main()
