"""Serving launcher: continuous-batching window-attention serving with ring
KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 6 --slots 2 --scan-steps 8 --batch-prefill

--scan-steps 1 --no-batch-prefill reproduces the seed engine's per-token
host-sync behavior (the serve_bench.py baseline).

Mesh-sharded serving: `--mesh 2x2` runs the engine under a data×model
device mesh (`--mesh 4x1` = pure slot-parallel; 2x2x2 = pod×data×model)
with `--profile` picking the param sharding rules. On CPU, force a debug
device count FIRST, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --smoke --mesh 2x2

Resilience drills (see serving/README.md "Resilience & fault injection"):
`--chaos-poison-slot 0` NaN-poisons a slot mid-decode and prints the
quarantine event; `--chaos-fail-pallas --decode-impl pallas` forces a
kernel dispatch failure and prints the ref-impl fallback. Per-request
`--deadline`, `--max-pending` backpressure and `--max-prompt-len`
rejection surface as per-status counts in the summary line.
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--swat", action="store_true",
                    help="swap dense attention for SWAT window attention")
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=2048)
    ap.add_argument("--scan-steps", type=int, default=8,
                    help="decode steps per host sync (1 = per-token sync)")
    ap.add_argument("--batch-prefill", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="pack pending prompts into one padded prefill "
                         "(on by default; --no-batch-prefill reverts to "
                         "one-prompt-at-a-time seed behavior)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="sequence-axis prefill chunk (0 = single-shot)")
    ap.add_argument("--max-prefill-tokens", type=int, default=8192)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--decode-impl", choices=("ref", "pallas"),
                    default="ref")
    ap.add_argument("--tokens-per-step", type=int, default=1,
                    help="ring lookahead for multi-token decode steps "
                         "(speculative-decode hook; tokens unchanged)")
    ap.add_argument("--speculative", type=int, default=0,
                    help="draft tokens per decode step (0 = sequential); "
                         "greedy output is token-identical either way")
    ap.add_argument("--draft-ngram", type=int, default=3,
                    help="n-gram drafter: longest context suffix to match")
    ap.add_argument("--draft-history", type=int, default=64,
                    help="n-gram drafter: per-slot token history length")
    ap.add_argument("--mesh", default=None,
                    help="device mesh 'DxM' (e.g. 2x2) — sharded serving; "
                         "default: single-device")
    ap.add_argument("--profile", choices=("tp", "cp", "fsdp"), default="tp",
                    help="param sharding profile for --mesh")
    # ----------------------------------------------- resilience knobs ----
    ap.add_argument("--max-prompt-len", type=int, default=0,
                    help="reject (status 'rejected') prompts longer than "
                         "this instead of serving them (0 = no limit)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="bounded-queue backpressure: reject requests "
                         "beyond this many queued (0 = unbounded)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline in seconds from submission "
                         "(0 = none); expired requests finalize as "
                         "status 'deadline' with whatever they emitted")
    ap.add_argument("--spec-min-acceptance", type=float, default=0.0,
                    help="auto-disable speculative decode when windowed "
                         "acceptance drops below this rate (0 = never)")
    # ------------------------------------------------- chaos drills -----
    ap.add_argument("--chaos-poison-slot", type=int, default=None,
                    metavar="SLOT",
                    help="fault drill: poison SLOT's logits with NaN at "
                         "--chaos-poison-step and watch it quarantine")
    ap.add_argument("--chaos-poison-step", type=int, default=3)
    ap.add_argument("--chaos-fail-pallas", action="store_true",
                    help="fault drill: make the pallas decode kernel "
                         "fail dispatch; the engine must fall back to "
                         "the reference impl and finish the batch")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="cache residency: per-slot rings or the paged "
                         "block pool + tables (see serving README)")
    # ------------------------------------------------- observability ----
    ap.add_argument("--metrics", action="store_true",
                    help="compile device-resident counters into the decode "
                         "scan (tokens identical; read at block boundaries) "
                         "and print a Prometheus summary after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of request lifecycle "
                         "spans to PATH (open in chrome://tracing / "
                         "Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition to PATH "
                         "(implies --metrics)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="prefill each admission batch's common prompt "
                         "prefix once and block-share it (paged, "
                         "single-device); demoed with a shared system "
                         "prompt across all requests")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.launch.mesh import parse_mesh
    from repro.serving import faults as F
    from repro.serving.drafter import NGramDrafter
    from repro.serving.engine import Request, ServingEngine, ring_cache_bytes
    from repro.serving.faults import FaultPlan

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.swat:
        cfg = with_swat(cfg, window=args.window, num_global=4)
    mesh = parse_mesh(args.mesh) if args.mesh else None
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    plan = FaultPlan(
        poison_logits=(((args.chaos_poison_slot, args.chaos_poison_step,
                         "nan"),)
                       if args.chaos_poison_slot is not None else ()),
        fail_pallas_dispatch=args.chaos_fail_pallas)
    engine = ServingEngine(
        cfg, params, batch_slots=args.slots, max_len=args.max_len,
        scan_steps=args.scan_steps, batch_prefill=args.batch_prefill,
        prefill_chunk=args.prefill_chunk,
        max_prefill_tokens=args.max_prefill_tokens,
        top_k=args.top_k, decode_impl=args.decode_impl,
        tokens_per_step=args.tokens_per_step,
        speculative=args.speculative,
        draft=NGramDrafter(max_ngram=args.draft_ngram,
                           history=args.draft_history),
        mesh=mesh, profile=args.profile,
        faults=plan,
        max_prompt_len=args.max_prompt_len or None,
        max_pending=args.max_pending or None,
        spec_min_acceptance=args.spec_min_acceptance,
        kv_layout=args.kv_layout, share_prefix=args.share_prefix,
        metrics=args.metrics or bool(args.metrics_out))
    rng = np.random.RandomState(0)
    if args.share_prefix:
        # shared-prefix demo workload: one system prompt, short suffixes
        sys_prompt = rng.randint(0, cfg.vocab_size,
                                 (max(args.prompt_len - 16, 16),)
                                 ).astype(np.int32)
        prompts = [np.concatenate(
            [sys_prompt, rng.randint(0, cfg.vocab_size, (16,)
                                     ).astype(np.int32)])
            for _ in range(args.requests)]
    else:
        prompts = [rng.randint(0, cfg.vocab_size, (args.prompt_len,)
                               ).astype(np.int32)
                   for _ in range(args.requests)]
    reqs = [Request(rid=i, prompt=prompts[i],
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature,
                    deadline=args.deadline or None)
            for i in range(args.requests)]
    t0 = time.time()
    try:
        results = engine.run(reqs)
    finally:
        F.clear_kernel_failure()
    dt = time.time() - t0
    n = sum(len(r.tokens) for r in results)
    mdesc = "single-device" if mesh is None else (
        "x".join(str(s) for s in mesh.devices.shape)
        + f" mesh ({args.profile})")
    spec = (f", speculative={args.speculative} "
            f"(acceptance {engine.acceptance_rate:.2f})"
            if args.speculative else "")
    print(f"[serve] {len(results)} requests / {n} tokens in {dt:.1f}s "
          f"({n / dt:.1f} tok/s; scan_steps={args.scan_steps}, "
          f"batch_prefill={args.batch_prefill}, "
          f"prefill_chunk={args.prefill_chunk}, {mdesc}{spec})")
    print(f"[serve] cache bytes @max_len: "
          f"{ring_cache_bytes(cfg, args.slots, args.max_len) / 1e6:.1f}MB")
    if args.kv_layout == "paged":
        ps = engine.paged_stats()
        line = (f"[serve] paged pool: {ps['blocks_in_use']}/"
                f"{ps['blocks_total']} blocks in use")
        if args.share_prefix:
            line += (f"; prefixes shared="
                     f"{engine.stats['prefill_prefix_shared']}, prefill "
                     f"tokens computed="
                     f"{engine.stats['prefill_tokens_computed']}")
        print(line)
    by_status = {}
    for r in results:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    print("[serve] statuses: "
          + ", ".join(f"{k}={v}" for k, v in sorted(by_status.items())))
    for ev in F.consume_events():
        kind = ev.pop("kind")
        print(f"[serve] degradation event: {kind} "
              + " ".join(f"{k}={v}" for k, v in sorted(ev.items())))
    for r in results:
        if r.status != "ok":
            print(f"[serve]   rid {r.rid}: {r.status}"
                  + (f" — {r.reason}" if r.reason else ""))

    lat = engine.tracer.latency_summary()
    if lat["ttft"]["count"]:
        ttft, tpot = lat["ttft"], lat["tpot"]
        print(f"[serve] latency: ttft p50={ttft['p50'] * 1e3:.1f}ms "
              f"p95={ttft['p95'] * 1e3:.1f}ms; "
              f"tpot p50={tpot['p50'] * 1e3:.2f}ms "
              f"p95={tpot['p95'] * 1e3:.2f}ms "
              f"({ttft['count']} finished)")
    if engine.metrics:
        dev = engine.device_metrics()
        print("[serve] device counters: "
              + " ".join(f"{k}={v}" for k, v in sorted(dev.items())))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics_text())
        print(f"[serve] wrote metrics: {args.metrics_out}")
    if args.trace_out:
        import json
        with open(args.trace_out, "w") as f:
            json.dump(engine.chrome_trace(), f)
        print(f"[serve] wrote trace: {args.trace_out}")


if __name__ == "__main__":
    main()
