"""swatscope CLI: inspect, validate, and profile serving telemetry.

Three subcommands, all off the hot path:

    # validate exported artifacts (the CI metrics lane)
    PYTHONPATH=src python -m repro.launch.scope validate \
        --trace /tmp/trace.json --metrics /tmp/metrics.prom

    # per-shape kernel latency + analytic roofline rows
    PYTHONPATH=src python -m repro.launch.scope profile \
        --impl ref --window 16 --cap 64 --batch 2 --heads-kv 2

    # trace-time dispatch census of a smoke serve (which kernel shapes
    # did the engine actually compile?)
    PYTHONPATH=src python -m repro.launch.scope census --arch llama3.2-1b

`validate` exits nonzero listing every schema problem; `profile` prints
one row per shape (p50/p95 latency, FLOPs, HBM bytes, intensity);
`census` runs a tiny instrumented serve and prints the deduped
(shape -> traces) map plus the engine snapshot.
"""
import argparse
import json
import sys


def _cmd_validate(args):
    from repro.telemetry import validate as V

    problems = []
    if args.trace:
        with open(args.trace) as f:
            doc = json.load(f)
        for p in V.validate_chrome_trace(doc):
            problems.append(f"{args.trace}: {p}")
        if not problems:
            n = len(doc.get("traceEvents", []))
            print(f"[scope] {args.trace}: valid chrome trace ({n} events)")
    if args.metrics:
        with open(args.metrics) as f:
            text = f.read()
        ms = V.validate_prometheus(text)
        for p in ms:
            problems.append(f"{args.metrics}: {p}")
        if not ms:
            n = sum(1 for ln in text.splitlines()
                    if ln.strip() and not ln.startswith("#"))
            print(f"[scope] {args.metrics}: valid prometheus exposition "
                  f"({n} samples)")
    if not args.trace and not args.metrics:
        print("[scope] nothing to validate (pass --trace and/or --metrics)")
        return 2
    for p in problems:
        print(f"[scope] INVALID: {p}")
    return 1 if problems else 0


def _cmd_profile(args):
    from repro.telemetry import kernelprof as KP

    shape = {"b": args.batch, "h_kv": args.heads_kv, "group": args.group,
             "t": args.tokens, "d": args.head_dim, "window": args.window,
             "num_global": args.num_global, "cap": args.cap}
    rows = KP.profile_decode([shape], impl=args.impl, iters=args.iters)
    for r in rows:
        print(f"[scope] {args.impl} b={r['b']} h_kv={r['h_kv']} "
              f"g={r.get('group', 1)} t={r['t']} d={r['d']} "
              f"window={r['window']} cap={r['cap']}: "
              f"p50={r['p50_us']:.1f}us p95={r['p95_us']:.1f}us "
              f"({r['flops'] / 1e6:.2f} MFLOP, "
              f"{r['hbm_bytes'] / 1e6:.2f} MB, "
              f"intensity={r['intensity']:.2f} flop/B, "
              f"band={r['band_rows']} rows)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"[scope] wrote {args.out}")
    return 0


def _cmd_census(args):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.serving.engine import Request, ServingEngine
    from repro.telemetry import kernelprof as KP

    cfg = with_swat(get_smoke_config(args.arch), window=args.window,
                    num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (16,)
                                       ).astype(np.int32),
                    max_new_tokens=8)
            for i in range(args.requests)]
    KP.enable_census(True)
    try:
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                            scan_steps=4, decode_impl=args.impl,
                            metrics=True)
        eng.run(reqs)
    finally:
        KP.enable_census(False)
    census = KP.consume_census()
    print(f"[scope] dispatch census: {len(census)} distinct kernel shapes")
    for rec in census:
        traces = rec.pop("traces")
        print("[scope]   " + " ".join(f"{k}={v}"
                                      for k, v in sorted(rec.items()))
              + f"  (traced {traces}x)")
    snap = eng.snapshot()
    print("[scope] engine snapshot: "
          + json.dumps(snap, sort_keys=True, default=str))
    return 0


def main():
    ap = argparse.ArgumentParser(prog="scope")
    sub = ap.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("validate", help="schema-check exported artifacts")
    v.add_argument("--trace", default=None,
                   help="chrome-trace JSON (from serve --trace-out)")
    v.add_argument("--metrics", default=None,
                   help="prometheus text (from serve --metrics-out)")
    v.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("profile", help="kernel latency + roofline rows")
    p.add_argument("--impl", choices=("ref", "pallas"), default="ref")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--heads-kv", type=int, default=2)
    p.add_argument("--group", type=int, default=2,
                   help="query heads per kv head (GQA group)")
    p.add_argument("--tokens", type=int, default=1)
    p.add_argument("--head-dim", type=int, default=64)
    p.add_argument("--window", type=int, default=16)
    p.add_argument("--num-global", type=int, default=4)
    p.add_argument("--cap", type=int, default=64,
                   help="physical ring rows (>= window+globals+tokens)")
    p.add_argument("--iters", type=int, default=20)
    p.add_argument("--out", default=None, help="write rows as JSON")
    p.set_defaults(fn=_cmd_profile)

    c = sub.add_parser("census", help="trace-time dispatch census of a "
                                      "smoke serve")
    c.add_argument("--arch", default="llama3.2-1b")
    c.add_argument("--impl", choices=("ref", "pallas"), default="ref")
    c.add_argument("--window", type=int, default=16)
    c.add_argument("--requests", type=int, default=4)
    c.set_defaults(fn=_cmd_census)

    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
