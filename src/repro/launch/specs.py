"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

Weak-type-correct, shardable, zero allocation. Covers the four assigned
shape cells (train_4k / prefill_32k / decode_32k / long_500k) for every
architecture family (LM, VLM-stub, audio-stub enc-dec, SSM, hybrid, MoE).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import model as Mod
from repro.core.types import ModelConfig, ShapeConfig

ENCODER_FRAMES = 1500    # whisper 30 s after the conv frontend (stubbed)

SDS = jax.ShapeDtypeStruct


def _act_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch specs for the step function this cell lowers
    (train/prefill: full sequence; decode: one token)."""
    b, l = shape.global_batch, shape.seq_len
    dt = _act_dtype(cfg)
    if shape.mode == "decode":
        return {"tokens": SDS((b, 1), jnp.int32)}
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        # stub: pre-fused patch+text embeddings
        batch["embeddings"] = SDS((b, l, cfg.d_model), dt)
    else:
        batch["tokens"] = SDS((b, l), jnp.int32)
    if cfg.encoder_decoder:
        batch["enc_embeddings"] = SDS((b, ENCODER_FRAMES, cfg.d_model), dt)
    if shape.mode == "train":
        batch["labels"] = SDS((b, l), jnp.int32)
    return batch


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    assert shape.mode == "decode"
    enc_len = ENCODER_FRAMES if cfg.encoder_decoder else 0
    return jax.eval_shape(
        lambda: Mod.init_caches(cfg, shape.global_batch, shape.seq_len,
                                enc_len=enc_len))


def param_count(cfg: ModelConfig) -> int:
    import math
    return sum(math.prod(x.shape) for x in jax.tree.leaves(param_specs(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: experts beyond top_k are inactive per token."""
    shapes = param_specs(cfg)
    total = 0
    def visit(path, x):
        nonlocal total
        n = 1
        for s in x.shape:
            n *= s
        p = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                     for k in path)
        if "moe/" in p and any(p.endswith(s) for s in ("w1", "w2", "w3")):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    jax.tree_util.tree_map_with_path(visit, shapes)
    return total
