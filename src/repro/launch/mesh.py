"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the "pod"
axis crosses the DCN; gradient all-reduce over ("pod","data") is
hierarchical (ICI within a pod, DCN across) under XLA's collective
hierarchy. Defined as functions so importing never touches device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pp_mesh(*, num_stages: int = 4, multi_pod: bool = False):
    """Pipeline-parallel production mesh: the 'pipe' axis takes chips from
    'data' (gradient sync shrinks; activations rotate stage-to-stage over
    ICI). Single pod (4, 4, 16) = 256 chips; multi-pod keeps stages inside
    a pod (cross-DCN activation hops would serialize the pipeline)."""
    assert 16 % num_stages == 0, num_stages
    if multi_pod:
        return jax.make_mesh((2, num_stages, 16 // num_stages, 16),
                             ("pod", "pipe", "data", "model"))
    return jax.make_mesh((num_stages, 16 // num_stages, 16),
                         ("pipe", "data", "model"))


def parse_mesh(spec: str):
    """Build a mesh from a 'DxM' launcher flag: '4x1' = 4-way data (slot)
    parallel, '2x2' = data×model, '2x2x2' = pod×data×model. The serving
    launcher threads this straight into ServingEngine(mesh=)."""
    dims = tuple(int(x) for x in spec.lower().replace("×", "x").split("x"))
    assert all(d >= 1 for d in dims), spec
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for multi-device CPU tests (subprocess sets device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_debug_pp_mesh(n_pipe: int = 2, n_data: int = 2):
    return jax.make_mesh((n_pipe, n_data), ("pipe", "data"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
