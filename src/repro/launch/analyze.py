"""swatlint driver: statically analyze the serving matrix, gate on the
committed ANALYSIS.json baseline.

    PYTHONPATH=src python -m repro.launch.analyze --check   # CI gate
    PYTHONPATH=src python -m repro.launch.analyze --write   # bless baseline

The matrix mirrors the serving configurations the test suite and
benchmarks exercise: single-host dense, window-attention pallas decode,
speculative decode, slot-parallel (4x1) and tensor-parallel (2x2) meshes
on a forced 4-device CPU topology. Everything is traced on
ShapeDtypeStructs — no real decoding happens; runtime is all XLA
compiles.

Exit codes: 0 clean, 1 baseline violations (new errors, warn growth,
lowering growth), 2 stale baseline (new engines/families — re-bless with
--write in the same PR that adds them).
"""
import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff against the committed ANALYSIS.json and "
                         "exit nonzero on violations")
    ap.add_argument("--write", action="store_true",
                    help="bless this run as the new ANALYSIS.json baseline")
    ap.add_argument("--baseline", default=None,
                    help="baseline path (default: repo-root ANALYSIS.json)")
    ap.add_argument("--engines", default=None,
                    help="comma list to restrict the matrix, e.g. "
                         "'single,tp_2x2'")
    ap.add_argument("--device-count", type=int, default=4)
    args = ap.parse_args()

    need = args.device_count
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need} " + flags)

    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.analysis import baselines, report as Rep
    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.launch.mesh import parse_mesh
    from repro.serving.engine import ServingEngine

    dense = get_smoke_config("llama3p2_1b")
    swat = with_swat(dense, window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), dense)
    swat_params = Mod.init_model(jax.random.PRNGKey(0), swat)

    def build(label):
        if label == "single":
            return ServingEngine(dense, params, batch_slots=2, max_len=128,
                                 scan_steps=4)
        if label == "swat_pallas":
            return ServingEngine(swat, swat_params, batch_slots=2,
                                 max_len=128, scan_steps=2,
                                 decode_impl="pallas")
        if label == "spec_k2":
            return ServingEngine(dense, params, batch_slots=2, max_len=128,
                                 scan_steps=4, speculative=2)
        if label == "slot_parallel_4x1":
            return ServingEngine(dense, params, batch_slots=4, max_len=128,
                                 scan_steps=4, mesh=parse_mesh("4x1"))
        if label == "tp_2x2":
            return ServingEngine(dense, params, batch_slots=2, max_len=128,
                                 scan_steps=4, mesh=parse_mesh("2x2"))
        if label == "paged_single":
            # paged pool + block tables on the swat config: admission is
            # the reshape-scatter insert, decode reads through the
            # gather-view — same math, different residency
            return ServingEngine(swat, swat_params, batch_slots=2,
                                 max_len=128, scan_steps=4,
                                 kv_layout="paged")
        if label == "paged_slot_parallel_4x1":
            # paged decode on the strictest topology: the local-id pool
            # shards over the slot axis and the scan must stay
            # collective-free — block gather/scatter is one-hot einsum
            # against the slot-local table shard, never cross-slot
            return ServingEngine(dense, params, batch_slots=4, max_len=128,
                                 scan_steps=4, mesh=parse_mesh("4x1"),
                                 kv_layout="paged")
        if label == "metrics_on":
            # the instrumented program on the strictest topology: the
            # device metrics pytree rides the scan carry, so the
            # telemetry rule family must prove it int32 / donated /
            # aliased and the host-sync + collective families must stay
            # clean with counters compiled in
            return ServingEngine(dense, params, batch_slots=4, max_len=128,
                                 scan_steps=4, mesh=parse_mesh("4x1"),
                                 metrics=True)
        if label == "chaos_4x1":
            # the fault-injected program on the strictest topology: logit
            # poison compiled into a slot-parallel decode scan must STILL
            # be collective-free and host-sync-free (the injection is one
            # masked row select + a countdown carry, all slot-local)
            from repro.serving.faults import FaultPlan
            return ServingEngine(
                dense, params, batch_slots=4, max_len=128, scan_steps=4,
                mesh=parse_mesh("4x1"),
                faults=FaultPlan(poison_logits=((0, 3, "nan"),)))
        raise SystemExit(f"unknown engine label: {label}")

    matrix = ["single", "swat_pallas", "spec_k2", "slot_parallel_4x1",
              "tp_2x2", "chaos_4x1", "paged_single",
              "paged_slot_parallel_4x1", "metrics_on"]
    if args.engines:
        matrix = [x.strip() for x in args.engines.split(",") if x.strip()]

    baseline = baselines.load(args.baseline)
    base_engines = (baseline or {}).get("engines") or {}

    per_engine = {}
    for label in matrix:
        print(f"[analyze] {label}: tracing...", flush=True)
        eng = build(label)
        per_engine[label] = Rep.analyze_engine(
            eng, label=label,
            baseline=None if args.write else base_engines.get(label))
        s = per_engine[label]["summary"]
        print(f"[analyze] {label}: {s['entries']} entries, "
              f"{s['errors']} errors, {s['warnings']} warnings", flush=True)

    fresh = Rep.merge_reports(per_engine, meta={
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "matrix": matrix,
    })

    if args.write:
        path = baselines.save(fresh, args.baseline)
        print(f"[analyze] wrote baseline: {path}")
        print(json.dumps(fresh["summary"]))
        return 0

    violations = baselines.diff(fresh, baseline)
    stale = baselines.is_stale(fresh, baseline)
    for v in violations:
        print(f"[analyze] VIOLATION: {v}")
    for s in stale:
        print(f"[analyze] STALE BASELINE: {s}")
    if not violations and not stale:
        print(f"[analyze] clean: {json.dumps(fresh['summary'])}")
    if args.check:
        return 1 if violations else (2 if stale else 0)
    return 0


if __name__ == "__main__":
    sys.exit(main())
