import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # mute absl/XLA warnings
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init. 512 host devices back both meshes:
  single pod : (16, 16)    axes (data, model)        — 256 chips
  multi-pod  : (2, 16, 16) axes (pod, data, model)   — 512 chips

For each cell this builds the real step function (train_step = fwd+bwd+AdamW;
serve_step = 1-token decode vs caches; prefill for the prefill cells),
shards params/optimizer/caches/batch with the rule tables in
distributed/sharding.py, lowers with ShapeDtypeStructs (no allocation),
compiles, and records memory_analysis / cost_analysis / collective traffic
to experiments/dryrun/<arch>__<shape>__<mesh>.json (incremental: existing
files are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --all --swat-variant    # beyond-paper cells
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCH_IDS, canonical, get_config, with_swat
from repro.core.types import ALL_SHAPES, ModelConfig, ShapeConfig
from repro.distributed import hlo_analysis as H
from repro.distributed import sharding as Sh
from repro.launch import analytic
from repro.launch import mesh as mesh_lib
from repro.launch import specs as Sp
from repro.launch import steps as St
from repro.optim import adamw

SHAPES = {s.name: s for s in ALL_SHAPES}

# dry-run only lowers+compiles (never executes): lower with TPU-native
# mixed-precision dots so the roofline sees no artificial fp32 copies
from repro.kernels import dots as _dots  # noqa: E402
_dots.native_mixed_dot(True)

# long_500k skip policy (DESIGN.md §4): pure full-attention archs skip in
# their faithful config; SSM/hybrid/local-attn archs run. whisper's decoder
# is structurally capped at 448 tokens.
LONG_CTX_OK = {"mamba2_1p3b", "jamba_1p5_large", "gemma2_2b"}
SKIP = {(a, "long_500k") for a in ARCH_IDS if a not in LONG_CTX_OK}


def out_dir() -> Path:
    d = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
    d.mkdir(parents=True, exist_ok=True)
    return d


def model_flops_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           n_devices: int) -> float:
    n_active = Sp.active_param_count(cfg)
    if shape.mode == "train":
        f = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.mode == "prefill":
        f = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        f = 2.0 * n_active * shape.global_batch
    return f / n_devices


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                out[attr] = int(getattr(ma, attr))
        if not out:
            out["repr"] = str(ma)
    except Exception as e:  # CPU backend may not implement it
        out["error"] = repr(e)
    return out


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               impl: str = "xla", sequence_parallel: bool = True,
               unroll: bool = True, profile: str = "tp",
               remat_policy: str = "nothing"):
    """Build + lower + compile one cell. Returns (compiled, lowered).

    unroll=True unrolls the layer scan so XLA's cost analysis (which counts
    a while body exactly once) sees every layer's FLOPs/bytes/collectives —
    required for honest roofline terms. Training itself keeps the rolled
    scan.

    profile='cp' switches to 2D-FSDP sharding + halo-exchange context
    parallelism for the window-attention layers; profile='fsdp' is the same
    parameter placement with batch-parallel compute (§Perf beyond-paper
    modes)."""
    from repro.core import moe as moe_lib
    from repro.kernels import ops as kops
    kops.set_context_parallel(mesh if profile == "cp" else None, "model")
    moe_lib.set_expert_parallel(mesh)
    batch_specs = Sp.input_specs(cfg, shape)
    p_specs = Sp.param_specs(cfg)
    p_shard = Sh.param_sharding(p_specs, mesh, profile=profile)
    b_shard = Sh.batch_sharding(batch_specs, mesh, profile=profile)

    if shape.mode == "train":
        opt_cfg = adamw.AdamWConfig()
        o_specs = jax.eval_shape(adamw.init_opt_state, p_specs)
        o_shard = adamw.OptState(step=Sh.replicated(mesh), mu=p_shard,
                                 nu=p_shard)
        act = jax.sharding.NamedSharding(
            mesh, Sh.activation_spec(mesh, sequence_parallel, profile))
        step = St.make_train_step(cfg, opt_cfg, impl=impl, act_sharding=act,
                                  unroll=unroll,
                                  remat_policy=("nothing"
                                                if remat_policy == "off"
                                                else remat_policy),
                                  remat=remat_policy != "off")
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(p_specs, o_specs, batch_specs)
    elif shape.mode == "prefill":
        step = St.make_prefill_step(cfg, max_len=shape.seq_len, impl=impl,
                                    unroll=unroll)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(p_specs, batch_specs)
    else:  # decode
        c_specs = Sp.cache_specs(cfg, shape)
        c_shard = Sh.cache_sharding(c_specs, mesh)
        step = St.make_serve_step(cfg, impl=impl, unroll=unroll)
        fn = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(None, c_shard), donate_argnums=(1,))
        lowered = fn.lower(p_specs, c_specs, batch_specs)
    compiled = lowered.compile()
    return compiled, lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             swat_variant: bool = False, impl: str = "xla",
             sequence_parallel: bool = True, tag: str = "",
             profile: str = "tp", moe_dispatch: str = "sort",
             remat_policy: str = "nothing", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if swat_variant:
        cfg = with_swat(cfg)
    if cfg.moe.enabled and moe_dispatch != cfg.moe.dispatch:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    n_dev = int(np.prod(list(mesh.shape.values())))

    # single-pod cells compile the UNROLLED stack so cost_analysis sees every
    # layer (the roofline table). multi-pod cells compile the production
    # rolled scan: the pass proves the pod-axis sharding is coherent, ~10x
    # faster, and is exactly what the trainer runs.
    unroll = not multi
    t0 = time.time()
    with mesh:
        compiled, lowered = lower_cell(cfg, shape, mesh, impl=impl,
                                       sequence_parallel=sequence_parallel,
                                       unroll=unroll, profile=profile,
                                       remat_policy=remat_policy)
    compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = H.parse_collectives(compiled.as_text())
    mf = model_flops_per_device(cfg, shape, n_dev)
    roof = H.roofline_terms(cost, coll, mf)
    mem = _memory_dict(compiled)

    rec = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_kind,
        "mode": shape.mode, "devices": n_dev, "impl": impl,
        "params": Sp.param_count(get_config(arch)),
        "active_params": Sp.active_param_count(get_config(arch)),
        "compile_s": round(compile_s, 2),
        "unrolled": unroll,
        "profile": profile,
        "memory": mem,
        "roofline": roof.to_dict(),
        "analytic_flops_per_device": analytic.step_flops(cfg, shape) / n_dev,
        "tag": tag,
    }
    if verbose:
        print(f"[dryrun] {cfg.name} x {shape.name} x {mesh_kind} "
              f"({n_dev} dev): compile={compile_s:.1f}s "
              f"flops/dev={roof.flops:.3e} bytes/dev={roof.bytes_accessed:.3e} "
              f"coll/dev={roof.collective_bytes:.3e} "
              f"dominant={roof.dominant} "
              f"roofline_frac={roof.roofline_fraction:.3f}")
        print(f"  memory_analysis: {mem}")
        print(f"  collectives: {roof.counts}")
    return rec


def cell_path(arch, shape_name, mesh_kind, swat_variant, tag="") -> Path:
    suffix = "+swat" if swat_variant else ""
    t = f"__{tag}" if tag else ""
    return out_dir() / f"{canonical(arch)}{suffix}__{shape_name}__{mesh_kind}{t}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--swat-variant", action="store_true",
                    help="beyond-paper: dense archs with SWAT window attn")
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--profile", default="tp", choices=["tp", "cp", "fsdp"],
                    help="cp: 2D-FSDP + halo-exchange context parallelism; "
                         "fsdp: 2D-FSDP, batch-parallel compute, no TP")
    ap.add_argument("--moe-dispatch", default="sort",
                    choices=["sort", "dense", "ep"])
    ap.add_argument("--remat", default="nothing",
                    choices=["nothing", "dots", "off"])
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel activation sharding")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            skip_set = SKIP if not args.swat_variant else {
                (canonical(a), "long_500k") for a in ("whisper_tiny",
                                                      "mamba2_1p3b")}
            if (canonical(arch), shape_name) in skip_set:
                print(f"[dryrun] SKIP {arch} x {shape_name} "
                      f"(policy: DESIGN.md §4)")
                continue
            if args.swat_variant and get_config(arch).is_attention_free:
                continue
            for mesh_kind in meshes:
                path = cell_path(arch, shape_name, mesh_kind,
                                 args.swat_variant, args.tag)
                if path.exists() and not args.force:
                    print(f"[dryrun] cached {path.name}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   swat_variant=args.swat_variant,
                                   impl=args.impl,
                                   sequence_parallel=not args.no_sp,
                                   profile=args.profile,
                                   moe_dispatch=args.moe_dispatch,
                                   remat_policy=args.remat,
                                   tag=args.tag)
                    path.write_text(json.dumps(rec, indent=2))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_kind, repr(e)))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll requested dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
