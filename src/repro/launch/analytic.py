"""Analytic per-step FLOP/byte model — exact matmul counting from the
config. Cross-checks the HLO cost analysis (useful_flops_ratio) and covers
any cell where full unrolling is too expensive to compile.

Conventions: 1 MAC = 2 FLOPs; training = fwd + 2x bwd (+1x fwd recompute
under full remat); attention FLOPs follow the spec's band/global columns
(SWAT's exact-band accounting, not sliding-chunks)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.types import AttentionSpec, ModelConfig, ShapeConfig


def _attn_cols(spec: AttentionSpec, seq: int) -> float:
    if not spec.is_sparse:
        return seq / 2 if spec.causal else seq
    cols = min(seq, (spec.window if spec.causal else 2 * spec.window) + 1)
    cols += min(spec.num_global, seq)
    cols += spec.num_random * 128          # random blocks (block_kv=128)
    return min(cols, seq)


def layer_flops_fwd(cfg: ModelConfig, kind: str, seq: int) -> float:
    """Per-token-free: FLOPs for `seq` tokens through one layer (fwd)."""
    dm, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    f = 0.0
    if kind.startswith("mamba"):
        s = cfg.ssm
        di = s.d_inner(dm)
        h = s.num_heads(dm)
        conv_dim = di + 2 * s.num_groups * s.state_dim
        f += 2 * seq * dm * (2 * di + 2 * s.num_groups * s.state_dim + h)
        f += 2 * seq * conv_dim * s.conv_width           # depthwise conv
        q = min(s.chunk_size, seq)
        f += 2 * seq * q * h * (s.head_dim + s.state_dim)    # intra-chunk
        f += 4 * seq * h * s.head_dim * s.state_dim          # states+inter
        f += 2 * seq * di * dm                               # out_proj
    else:
        spec = (cfg.local_attention if kind == "local_attn"
                else cfg.attention)
        cols = _attn_cols(spec, seq)
        f += 2 * seq * dm * dh * (hq + 2 * hkv)              # qkv proj
        f += 2 * seq * hq * cols * dh * 2                    # QK^T + PV
        f += 2 * seq * hq * dh * dm                          # out proj
    if kind == "xattn":
        enc = 1500
        f += 2 * seq * dm * dh * hq + 2 * enc * dm * dh * 2 * hkv
        f += 2 * seq * hq * enc * dh * 2
        f += 2 * seq * hq * dh * dm
    if kind.endswith("_moe") and cfg.moe.enabled:
        f += 2 * seq * dm * cfg.moe.num_experts              # router
        f += 6 * seq * cfg.moe.top_k * dm * cfg.d_ff         # active experts
    elif cfg.d_ff > 0 and not kind.startswith("mamba_moe"):
        f += 6 * seq * dm * cfg.d_ff                         # gated MLP
    return f


def step_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Whole-step FLOPs (all devices)."""
    b = shape.global_batch
    seq = 1 if shape.mode == "decode" else shape.seq_len
    per_layer = sum(layer_flops_fwd(cfg, k, seq)
                    for k in cfg.layer_pattern) * cfg.num_super_blocks
    head = 2 * seq * cfg.d_model * cfg.vocab_size
    if cfg.encoder_decoder and shape.mode != "decode":
        enc = 1500
        per_layer += cfg.encoder_layers * (
            2 * enc * cfg.d_model * cfg.resolved_head_dim
            * (cfg.num_heads + 2 * cfg.num_kv_heads)
            + 2 * enc * cfg.num_heads * enc * cfg.resolved_head_dim * 2
            + 2 * enc * cfg.num_heads * cfg.resolved_head_dim * cfg.d_model
            + 6 * enc * cfg.d_model * cfg.d_ff)
    if shape.mode == "decode":
        # decode attention reads the whole cache: cols = cache length
        cache_flops = 0.0
        for k in cfg.layer_pattern:
            if k.startswith("mamba"):
                continue
            spec = (cfg.local_attention if k == "local_attn"
                    else cfg.attention)
            cap = (min(spec.window + 1 + spec.num_global, shape.seq_len)
                   if spec.is_sparse else shape.seq_len)
            cache_flops += 2 * cfg.num_heads * cap \
                * cfg.resolved_head_dim * 2
        per_layer += cache_flops * cfg.num_super_blocks
    total_fwd = (per_layer + head) * b
    if shape.mode == "train":
        return total_fwd * 4.0      # fwd + bwd(2x) + remat recompute(1x)
    return total_fwd


def step_param_bytes(cfg: ModelConfig, n_params: int,
                     shape: ShapeConfig) -> float:
    """Minimum parameter traffic per step (each param read once, bf16;
    training adds grad write + fp32 optimizer read/write)."""
    if shape.mode == "train":
        return n_params * (2 + 2 + 2 + 16 + 8)   # p, g(w+r), m/v rw fp32
    return n_params * 2.0
