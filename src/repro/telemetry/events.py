"""The unified degradation-event bus (one stream, not two).

Before swatscope, structured degradation events lived in a module-global
list inside `serving/faults.py` while the tracer would have needed its own
copy — two half-buses. This module is now the single store:

  * `record_event(kind, **details)` appends to the process-global queue
    AND fans out to every subscribed sink (engine tracers subscribe their
    bounded ring buffers via weakrefs, so a garbage-collected engine
    never leaks a subscription).
  * `consume_events()` / `peek_events()` keep the historical drain
    semantics every resilience test and bench asserts against.
  * `serving/faults.py` re-exports these names as a thin back-compat
    shim — its own `_EVENTS` list (the duplicate consume path) is gone.

Events are plain dicts with a "kind" key, mirroring
`swat_decode._PAD_EVENTS`. The queue is host-side Python only — nothing
here ever touches a device buffer or a jit trace.
"""
from __future__ import annotations

import weakref
from typing import Callable, List


class EventBus:
    """A consumable event queue plus weakly-held subscriber fan-out."""

    def __init__(self):
        self._events: List[dict] = []
        self._subs: List[weakref.WeakMethod] = []

    def record(self, kind: str, **details) -> None:
        ev = {"kind": kind, **details}
        self._events.append(ev)
        if self._subs:
            alive = []
            for wm in self._subs:
                cb = wm()
                if cb is not None:
                    cb(dict(ev))
                    alive.append(wm)
            self._subs = alive

    def consume(self) -> List[dict]:
        out, self._events[:] = list(self._events), []
        return out

    def peek(self) -> List[dict]:
        return list(self._events)

    def subscribe(self, bound_method: Callable[[dict], None]) -> None:
        """Fan events out to `bound_method(event_dict)` — held via
        WeakMethod, so the subscription dies with its owner."""
        self._subs.append(weakref.WeakMethod(bound_method))


BUS = EventBus()


def record_event(kind: str, **details) -> None:
    """Record one structured degradation event (quarantine, fallback,
    rejection, deadline, spec disable/resume...) on the global bus."""
    BUS.record(kind, **details)


def consume_events() -> List[dict]:
    """Drain the global queue (subscribed tracers keep their copies)."""
    return BUS.consume()


def peek_events() -> List[dict]:
    return BUS.peek()
