"""Device-resident decode counters (swatscope layer 1).

A `Metrics` pytree of int32 counters rides the decode / spec-verify scan
bodies as one extra carry — exactly like the ring caches: donated, never
read mid-block, slot-sharded under a mesh so slot-parallel decode stays
collective-free. Every update is a pure per-slot add derived from values
the body already computes (`ok` / `e` / `bad`), so a metrics-on engine's
tokens are bitwise a metrics-off engine's (the test_telemetry.py
contract) and the only cost is a handful of elementwise int32 ops per
step — measured < 3% tok/s at smoke scale (BENCH_serve.json
`telemetry`).

Counters (per slot unless noted):

  tokens           decode tokens emitted (prefill-sampled tokens are
                   host-side; see ServingEngine.stats)
  drafts_proposed  speculative drafts offered to the verifier
  drafts_accepted  drafts the verifier kept
  quarantined      numerical-guard trips (non-finite logits rows)
  ring_wraps       completed ring revolutions of decode writes, counted
                   against the engine's NARROWEST ring (`ring_modulus`);
                   cumulative across slot occupants like every counter
  pos              cumulative decode-write count (ring_wraps' phase
                   accumulator; also per-slot work done)
  steps            scalar: executed scan/verify iterations (replicated
                   under a mesh — increments identically on every
                   device, no collective)

The host reads these ONLY at `ServingEngine.device_metrics()` — an
explicit, scheduled sync outside the decode transfer guard, never inside
a block.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

PER_SLOT = ("tokens", "drafts_proposed", "drafts_accepted",
            "quarantined", "ring_wraps", "pos")
SCALARS = ("steps",)

COUNTER_DOC: Dict[str, str] = {
    "tokens": "decode tokens emitted on device",
    "drafts_proposed": "speculative draft tokens offered to the verifier",
    "drafts_accepted": "speculative draft tokens the verifier kept",
    "quarantined": "numerical-guard quarantine trips",
    "ring_wraps": "completed ring-cache revolutions of decode writes",
    "pos": "cumulative decode ring writes per slot",
    "steps": "executed decode/verify scan iterations",
}


def init_metrics(slots: int) -> Dict[str, Any]:
    """Fresh all-zero counters (device arrays via jnp; the engine
    device_puts them onto the mesh with `metrics_shardings`)."""
    mx = {k: jnp.zeros((slots,), jnp.int32) for k in PER_SLOT}
    for k in SCALARS:
        mx[k] = jnp.zeros((), jnp.int32)
    return mx


def metrics_shardings(veci, rep) -> Dict[str, Any]:
    """Sharding pytree matching `init_metrics`: per-slot vectors ride the
    slot axis (`veci`, the engine's decode_batch_sharding), the scalar
    step counter is replicated (`rep`)."""
    sh = {k: veci for k in PER_SLOT}
    for k in SCALARS:
        sh[k] = rep
    return sh


def ring_modulus(cfg, max_len: int, lookahead: int = 0) -> int:
    """The wrap modulus for the `ring_wraps` counter: the LOGICAL ring
    capacity of the engine's narrowest attention cache (a dense config's
    "ring" is the full context — it wraps never in practice). Static per
    engine, baked into the compiled scan as a constant."""
    from repro.core.layers import cache_capacity
    from repro.core.model import attn_cfg
    caps = []
    for i, kind in enumerate(cfg.layer_pattern):
        if kind.startswith("mamba"):
            continue
        caps.append(cache_capacity(attn_cfg(cfg, kind, index=i), max_len,
                                   lookahead))
    return max(1, min(caps)) if caps else max(1, max_len)


def _wraps(mx: Dict[str, Any], pos, ring_mod: int):
    """Ring revolutions completed by advancing `pos` from its carried
    value: floor(new/mod) - floor(old/mod). Non-negative (pos only
    grows); with per-step emission <= lookahead+1 << mod it is 0 or 1."""
    return mx["ring_wraps"] + (pos // ring_mod - mx["pos"] // ring_mod)


def seq_update(mx: Dict[str, Any], ok, bad, ring_mod: int
               ) -> Dict[str, Any]:
    """One sequential decode step: `ok` (slots,) bool = emitted this
    step, `bad` (slots,) bool = guard-quarantined this step. Pure adds —
    no effect on sampling, RNG, or control flow."""
    e = ok.astype(jnp.int32)
    pos = mx["pos"] + e
    return {
        "tokens": mx["tokens"] + e,
        "drafts_proposed": mx["drafts_proposed"],
        "drafts_accepted": mx["drafts_accepted"],
        "quarantined": mx["quarantined"] + bad.astype(jnp.int32),
        "ring_wraps": _wraps(mx, pos, ring_mod),
        "pos": pos,
        "steps": mx["steps"] + 1,
    }


def spec_update(mx: Dict[str, Any], e, bad, k: int, ring_mod: int
                ) -> Dict[str, Any]:
    """One speculative verify step: `e` (slots,) int32 = tokens emitted
    (accepted drafts + bonus, 0 for inactive slots), `bad` = guard trip,
    `k` = drafts proposed per active slot. Mirrors the host-side
    accounting in `_decode_block` exactly: a slot that ran (e >= 1)
    proposed k drafts and kept e - 1 of them."""
    ran = (e >= 1).astype(jnp.int32)
    pos = mx["pos"] + e
    return {
        "tokens": mx["tokens"] + e,
        "drafts_proposed": mx["drafts_proposed"] + k * ran,
        "drafts_accepted": mx["drafts_accepted"]
        + jnp.maximum(e - 1, 0) * ran,
        "quarantined": mx["quarantined"] + bad.astype(jnp.int32),
        "ring_wraps": _wraps(mx, pos, ring_mod),
        "pos": pos,
        "steps": mx["steps"] + 1,
    }
