"""Kernel profiling hooks (swatscope layer 3) — opt-in, never hot-path.

Three tools that feed the shape-adaptive-dispatch roadmap item with real
data instead of guesswork:

  dispatch census   `kernels/ops.py` / `swat_decode.py` call
                    `record_dispatch()` at TRACE time when the census is
                    enabled — jit traces once per shape, so the census is
                    a complete (shape -> dispatch count) map of what the
                    engine actually compiled, at zero runtime cost (the
                    compiled program is byte-identical; nothing executes
                    per step).
  analytic roofline `banded_decode_cost()` — FLOPs over the logical
                    banded geometry (window + globals + lookahead, the
                    paper's O(window) argument) and HBM bytes over the
                    physical ring rows a decode step actually touches;
                    intensity = flops/bytes locates each shape on the
                    roofline.
  latency sampler   `sample_latency()` / `profile_decode()` — standalone
                    timed dispatches (block_until_ready, medians over
                    iters) for per-shape block-latency rows, the same
                    measurement discipline as benchmarks/common.time_fn.

Census state is module-global and OFF by default — `enable_census()` in
a `try/finally` like `faults.install_kernel_failure`.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

_CENSUS_ON = False
_CENSUS: Dict[Tuple, Dict[str, Any]] = {}


def enable_census(on: bool = True) -> None:
    """Start (or stop) recording kernel dispatch traces. Trace-time only:
    enabling this never changes a compiled program or adds runtime work."""
    global _CENSUS_ON
    _CENSUS_ON = on


def census_enabled() -> bool:
    return _CENSUS_ON


def record_dispatch(**fields) -> None:
    """Record one kernel trace event (deduped by field tuple; `traces`
    counts how many times jit traced this exact shape)."""
    if not _CENSUS_ON:
        return
    key = tuple(sorted((k, repr(v)) for k, v in fields.items()))
    rec = _CENSUS.get(key)
    if rec is None:
        _CENSUS[key] = {**fields, "traces": 1}
    else:
        rec["traces"] += 1


def consume_census() -> List[Dict[str, Any]]:
    """Drain the census (insertion order)."""
    out = list(_CENSUS.values())
    _CENSUS.clear()
    return out


# ------------------------------------------------------------- roofline ---

def banded_decode_cost(*, b: int, h_q: int, h_kv: int, t: int, d: int,
                       window: int, num_global: int = 0,
                       cap: Optional[int] = None,
                       dtype_bytes: int = 2,
                       fused: bool = True) -> Dict[str, float]:
    """Analytic cost of one T-token banded decode step.

    FLOPs count the LOGICAL band each query row attends — min(cap,
    window + globals + T) rows for sparse specs, the whole cap for dense
    (window=0 means dense here) — with 2*d per QK and AV MAC plus ~4 ops
    per softmax cell. Bytes count the PHYSICAL traffic: both ring caches
    streamed once (cap rows), q read, out written, and the fused
    insert's T new K/V rows written back. intensity (flops/byte) tells
    you which side of the roofline ridge the shape sits on — decode is
    classically bandwidth-bound, which is why the fused kernel's single
    cache pass is the whole game."""
    assert cap is not None and cap >= 1
    band = min(cap, window + num_global + t) if window else cap
    q_rows = b * h_q * t
    flops = q_rows * band * (4 * d + 4)
    bytes_ = (2 * b * h_kv * cap * d * dtype_bytes      # K+V stream
              + q_rows * d * dtype_bytes                # q read
              + q_rows * d * dtype_bytes)               # out write
    if fused:
        bytes_ += 2 * b * h_kv * t * d * dtype_bytes    # ring insert
    return {"flops": float(flops), "hbm_bytes": float(bytes_),
            "intensity": float(flops) / float(bytes_), "band_rows": band}


# ------------------------------------------------------- latency sampling --

def sample_latency(fn, *args, iters: int = 30, warmup: int = 3
                   ) -> Dict[str, float]:
    """Block-latency samples of one jitted dispatch: median / p95 /
    best, in microseconds. Synchronizes every call (block_until_ready) —
    which is exactly why this lives OUTSIDE the engine hot path."""
    import jax
    import numpy as np
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    arr = np.asarray(ts)
    return {"p50_us": float(np.percentile(arr, 50)),
            "p95_us": float(np.percentile(arr, 95)),
            "best_us": float(arr.min()), "iters": int(arr.size)}


def profile_decode(shapes: List[Dict[str, int]], *, impl: str = "ref",
                   interpret: Optional[bool] = None, iters: int = 20,
                   seed: int = 0) -> List[Dict[str, Any]]:
    """Per-shape block-latency + roofline rows for the fused decode op.

    Each shape dict: {b, h_kv, group, t, d, window, num_global, cap}
    (cap = physical ring rows; must satisfy the fused-insert geometry,
    cap >= window + globals + t for windowed specs). Returns one row per
    shape merging measured latency with the analytic cost — the
    autotune-table feedstock."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.types import AttentionSpec
    from repro.kernels import ops

    rows: List[Dict[str, Any]] = []
    rng = np.random.RandomState(seed)
    for sh in shapes:
        b, h_kv, group = sh["b"], sh["h_kv"], sh.get("group", 1)
        t, d, cap = sh.get("t", 1), sh["d"], sh["cap"]
        window, g = sh.get("window", 0), sh.get("num_global", 0)
        h_q = h_kv * group
        spec = (AttentionSpec(kind="swat", window=window, num_global=g)
                if window else AttentionSpec(kind="dense"))
        q = jnp.asarray(rng.randn(b, h_q, t, d), jnp.float32)
        kc = jnp.asarray(rng.randn(b, h_kv, cap, d), jnp.bfloat16)
        vc = jnp.asarray(rng.randn(b, h_kv, cap, d), jnp.bfloat16)
        nk = jnp.asarray(rng.randn(b, h_kv, t, d), jnp.bfloat16)
        nv = jnp.asarray(rng.randn(b, h_kv, t, d), jnp.bfloat16)
        pos = jnp.full((b,), max(cap - t, g), jnp.int32)

        fn = jax.jit(lambda q, kc, vc, nk, nv, pos: ops.decode_attention(
            q, kc, vc, None, spec, impl=impl, interpret=interpret,
            new_kv=(nk, nv), pos=pos, ring_cap=cap)[0])
        lat = sample_latency(fn, q, kc, vc, nk, nv, pos, iters=iters)
        cost = banded_decode_cost(b=b, h_q=h_q, h_kv=h_kv, t=t, d=d,
                                  window=window, num_global=g, cap=cap)
        us = max(lat["p50_us"], 1e-9)
        rows.append({**sh, "impl": impl, **lat, **cost,
                     "achieved_gflops": cost["flops"] / us / 1e3,
                     "achieved_gbps": cost["hbm_bytes"] / us / 1e3})
    return rows
