"""Schema validation for exported telemetry artifacts (the CI metrics
lane). No jsonschema dependency — the schemas are small enough to check
by hand, and the point is actionable error strings, not spec coverage.

  validate_chrome_trace(doc)   Chrome trace event format: traceEvents
                               list, every event carries name/ph/pid/tid,
                               duration events carry numeric ts/dur >= 0.
  validate_prometheus(text)    text exposition format 0.0.4: every
                               sample line is `name[{labels}] value`,
                               every # TYPE names a known metric type,
                               and at least one sample exists.

Both return a list of problem strings — empty means valid (the
`scope --validate` CLI and tests assert on that).
"""
from __future__ import annotations

import re
from typing import Any, List

_PH = set("BEXiIMPNODSTFsfbenC(")
_TYPES = {"counter", "gauge", "summary", "histogram", "untyped"}
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"          # metric name
    r"(\{[^}]*\})?"                          # optional labels
    r"\s+(-?[0-9.eE+-]+|NaN|[+-]Inf)"        # value
    r"(\s+-?\d+)?$")                         # optional timestamp


def validate_chrome_trace(doc: Any) -> List[str]:
    problems: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level must be an object, got {type(doc).__name__}"]
    tev = doc.get("traceEvents")
    if not isinstance(tev, list):
        return ["missing/invalid traceEvents (must be a list)"]
    if not tev:
        problems.append("traceEvents is empty")
    for i, ev in enumerate(tev):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                problems.append(f"{where}: missing `{k}`")
        ph = ev.get("ph")
        if ph is not None and ph not in _PH:
            problems.append(f"{where}: unknown phase {ph!r}")
        if ph == "X":
            for k in ("ts", "dur"):
                v = ev.get(k)
                if not isinstance(v, (int, float)) or v < 0:
                    problems.append(
                        f"{where}: duration event needs numeric {k} >= 0, "
                        f"got {v!r}")
        elif ph in ("B", "E", "i", "I"):
            if not isinstance(ev.get("ts"), (int, float)):
                problems.append(f"{where}: needs numeric ts")
    return problems


def validate_prometheus(text: str) -> List[str]:
    problems: List[str] = []
    typed: dict = {}
    samples = 0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in _TYPES:
                problems.append(f"line {ln}: malformed TYPE comment")
            else:
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            if not line.startswith(("# HELP ", "# TYPE ", "# EOF")):
                problems.append(f"line {ln}: unknown comment form")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            problems.append(f"line {ln}: not a valid sample: {line!r}")
            continue
        samples += 1
        name = m.group(1)
        base = re.sub(r"_(count|sum|bucket)$", "", name)
        if name not in typed and base not in typed:
            problems.append(f"line {ln}: sample `{name}` has no # TYPE")
    if samples == 0:
        problems.append("no samples in exposition")
    return problems
