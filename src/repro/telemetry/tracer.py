"""Host-side request tracing (swatscope layer 2).

A `Tracer` records per-Request lifecycle timestamps (submit -> queued ->
admitted -> prefill/first token -> decode blocks -> done/degraded),
decode-block spans, and the unified degradation-event stream — all in
bounded ring buffers (`collections.deque(maxlen=capacity)`), so a
sustained-load engine holds O(capacity) trace memory forever
(test_telemetry.py pins this).

Derived latencies per finished request:

  queue_delay  submit -> admission (last attempt's admission for retried
               requests — stats are PER ATTEMPT, a retry restarts the
               prefill clock but never the submit clock)
  ttft         submit -> first sampled token of the attempt that
               finalized (time-to-first-token as the CLIENT sees it:
               tokens from a failed attempt died with its slot)
  tpot         (finish - first token) / (tokens - 1): steady-state
               time-per-output-token; resolution is one decode block
               (the host-sync quantum — the tracer never adds syncs)

Exports: `chrome_trace()` (load in chrome://tracing / Perfetto) and
`prometheus_text()` (text exposition, scrape or diff in CI). The clock
is injectable for deterministic tests.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    """One finished request's lifecycle timestamps (tracer clock)."""
    rid: int
    submit: float
    admit: Optional[float]          # None: rejected before admission
    first_token: Optional[float]
    finish: float
    tokens: int
    status: str
    attempts: int = 1               # admissions consumed (1 + retries)

    @property
    def queue_delay(self) -> Optional[float]:
        return None if self.admit is None else self.admit - self.submit

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token is None
                else self.first_token - self.submit)

    @property
    def tpot(self) -> Optional[float]:
        if self.first_token is None or self.tokens <= 1:
            return None
        return (self.finish - self.first_token) / (self.tokens - 1)


class Tracer:
    """Ring-buffered lifecycle tracer. All hooks are O(1) host Python —
    no device work, no syncs; the engine calls them strictly outside the
    transfer-guarded block dispatch."""

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.monotonic):
        assert capacity >= 1
        self.capacity = capacity
        self.clock = clock
        self.epoch = clock()
        self.records: Deque[RequestRecord] = collections.deque(
            maxlen=capacity)
        self.blocks: Deque[Dict[str, Any]] = collections.deque(
            maxlen=capacity)
        self.events: Deque[Dict[str, Any]] = collections.deque(
            maxlen=capacity)
        self._open: Dict[int, Dict[str, Any]] = {}
        self.dropped_requests = 0     # finalize seen without a submit

    # ------------------------------------------------------------ lifecycle
    def on_submit(self, rid: int) -> None:
        self._open[rid] = {"submit": self.clock(), "admit": None,
                           "first_token": None, "attempts": 0}

    def on_admit(self, rids) -> None:
        """One admission batch entered prefill. A rid admitted AGAIN is a
        retry: the attempt counter bumps and the first-token clock resets
        (per-attempt stats), while submit stays — the client queued once."""
        t = self.clock()
        for rid in rids:
            rec = self._open.get(rid)
            if rec is None:
                rec = self._open[rid] = {"submit": t, "admit": None,
                                         "first_token": None, "attempts": 0}
            rec["admit"] = t
            rec["first_token"] = None
            rec["attempts"] += 1

    def on_first_token(self, rids) -> None:
        t = self.clock()
        for rid in rids:
            rec = self._open.get(rid)
            if rec is not None and rec["first_token"] is None:
                rec["first_token"] = t

    def on_block(self, mode: str, n: int, t0: float, tokens: int) -> None:
        """One decode block span: t0 from `clock()` before dispatch, the
        span closes at the host sync draining the block's outputs."""
        self.blocks.append({"mode": mode, "n": n, "t0": t0,
                            "dur": self.clock() - t0, "tokens": tokens})

    def on_finish(self, rid: int, status: str, tokens: int) -> None:
        t = self.clock()
        rec = self._open.pop(rid, None)
        if rec is None:
            self.dropped_requests += 1
            rec = {"submit": t, "admit": None, "first_token": None,
                   "attempts": 0}
        self.records.append(RequestRecord(
            rid=rid, submit=rec["submit"], admit=rec["admit"],
            first_token=rec["first_token"], finish=t, tokens=tokens,
            status=status, attempts=max(1, rec["attempts"])))

    def on_bus_event(self, ev: dict) -> None:
        """EventBus subscription sink — stamps arrival time."""
        self.events.append({"ts": self.clock(), **ev})

    # ------------------------------------------------------------- summary
    def _samples(self, field: str) -> List[float]:
        return [v for r in self.records
                if (v := getattr(r, field)) is not None]

    @staticmethod
    def percentiles(samples: List[float]) -> Dict[str, float]:
        if not samples:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "count": 0}
        arr = np.asarray(samples, np.float64)
        return {"p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
                "p99": float(np.percentile(arr, 99)),
                "count": int(arr.size)}

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        """p50/p95/p99 of the three derived latencies, in seconds, over
        the retained record window."""
        return {"ttft": self.percentiles(self._samples("ttft")),
                "tpot": self.percentiles(self._samples("tpot")),
                "queue_delay": self.percentiles(
                    self._samples("queue_delay"))}

    # ------------------------------------------------------------- exports
    def _us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def chrome_trace(self, *, metadata: Optional[dict] = None) -> dict:
        """Chrome-trace JSON (chrome://tracing, Perfetto): request phases
        on pid 1 (one tid per rid), decode-block spans on pid 0 tid 0,
        degradation events as instants on pid 0 tid 1."""
        tev: List[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "engine"}},
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "requests"}},
        ]
        for b in self.blocks:
            tev.append({"name": f"{b['mode']}_block[n={b['n']}]",
                        "cat": "decode", "ph": "X", "pid": 0, "tid": 0,
                        "ts": self._us(b["t0"]), "dur": b["dur"] * 1e6,
                        "args": {"tokens": b["tokens"]}})
        for ev in self.events:
            args = {k: v for k, v in ev.items() if k not in ("ts", "kind")}
            tev.append({"name": ev.get("kind", "event"), "cat": "degrade",
                        "ph": "i", "pid": 0, "tid": 1, "s": "g",
                        "ts": self._us(ev["ts"]), "args": args})
        for r in self.records:
            tid = r.rid
            phases = []
            if r.admit is not None:
                phases.append(("queued", r.submit, r.admit))
                end_first = (r.first_token if r.first_token is not None
                             else r.finish)
                phases.append(("prefill", r.admit, end_first))
                if r.first_token is not None:
                    phases.append(("decode", r.first_token, r.finish))
            else:
                phases.append((r.status, r.submit, r.finish))
            for name, t0, t1 in phases:
                tev.append({"name": f"req{r.rid}:{name}", "cat": "request",
                            "ph": "X", "pid": 1, "tid": tid,
                            "ts": self._us(t0),
                            "dur": max(t1 - t0, 0.0) * 1e6,
                            "args": {"status": r.status,
                                     "tokens": r.tokens,
                                     "attempts": r.attempts}})
        return {"traceEvents": tev, "displayTimeUnit": "ms",
                "metadata": metadata or {}}


def prometheus_text(counters: Dict[str, Any],
                    latency: Optional[Dict[str, Dict[str, float]]] = None,
                    *, prefix: str = "swat",
                    doc: Optional[Dict[str, str]] = None) -> str:
    """Prometheus text exposition: integer/float `counters` become
    counters, `latency` summaries become quantile-labeled summary
    metrics. Plain text format 0.0.4 — parseable by `validate.py` and any
    Prometheus scraper."""
    lines: List[str] = []
    for name in sorted(counters):
        metric = f"{prefix}_{_sanitize(name)}"
        help_ = (doc or {}).get(name, name.replace("_", " "))
        lines.append(f"# HELP {metric} {help_}")
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric} {_fmt(counters[name])}")
    for name in sorted(latency or {}):
        q = latency[name]
        metric = f"{prefix}_{_sanitize(name)}_seconds"
        lines.append(f"# HELP {metric} {name} latency quantiles (seconds)")
        lines.append(f"# TYPE {metric} summary")
        for k, label in (("p50", "0.5"), ("p95", "0.95"), ("p99", "0.99")):
            lines.append(f'{metric}{{quantile="{label}"}} '
                         f'{_fmt(q.get(k, 0.0))}')
        lines.append(f"{metric}_count {int(q.get('count', 0))}")
    return "\n".join(lines) + "\n"


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def _fmt(v) -> str:
    if isinstance(v, (bool, np.bool_)):
        return str(int(v))
    if isinstance(v, (int, np.integer)):
        return str(int(v))
    return repr(float(v))
