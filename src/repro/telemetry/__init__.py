"""swatscope: hot-path-safe observability for the serving stack.

Three layers, one contract — telemetry must never change what the hot
path computes or how it runs:

  device   `metrics.py` — a tiny int32 counter pytree carried through the
           decode / spec-verify scan bodies (tokens emitted, drafts
           proposed/accepted, guard-quarantine trips, ring wraps). Pure
           additions to the carry, donated like the ring caches, never
           read inside a block: the steady-state transfer_guard and the
           collective-free slot-parallel proof hold with metrics on, and
           tokens are bitwise identical to a metrics-off engine
           (tests/test_telemetry.py). swatlint's `metrics_on` engine +
           `telemetry` rule family pin this statically.
  host     `tracer.py` + `events.py` — a ring-buffered Tracer recording
           per-Request lifecycle spans (submit -> queued -> admitted ->
           prefill -> decode blocks -> done/degraded) with TTFT / TPOT /
           queue-delay, fed by the unified degradation-event bus that
           `serving/faults.py` now delegates to (one event stream, not
           two). Exports Chrome-trace JSON and a Prometheus-style text
           exposition (`ServingEngine.metrics_text()`).
  kernel   `kernelprof.py` — opt-in dispatch census (trace-time, zero
           runtime cost) + analytic FLOP/byte roofline from the banded
           decode geometry + a block-latency sampler; the data feed for
           the shape-adaptive-dispatch roadmap item.

`validate.py` schema-checks the exported artifacts (the CI metrics lane);
`repro.launch.scope` pretty-prints a live engine snapshot.
"""
from repro.telemetry import events, kernelprof, metrics, tracer, validate
from repro.telemetry.events import (consume_events, peek_events,
                                    record_event)
from repro.telemetry.metrics import (COUNTER_DOC, init_metrics,
                                     metrics_shardings, ring_modulus,
                                     seq_update, spec_update)
from repro.telemetry.tracer import Tracer

__all__ = [
    "events", "kernelprof", "metrics", "tracer", "validate",
    "record_event", "consume_events", "peek_events",
    "COUNTER_DOC", "init_metrics", "metrics_shardings", "ring_modulus",
    "seq_update", "spec_update", "Tracer",
]
