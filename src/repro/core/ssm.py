"""Mamba2 SSD (state-space duality) layer, chunked block form.

The SSD dual form is structurally kin to SWAT's banded attention: within a
chunk the computation is a (decay-masked) quadratic attention; across chunks
a linear recurrence carries the (H, P, S) state — i.e. block-banded compute
plus a running summary, which is why it slots into the same scan-over-blocks
machinery (DESIGN.md §4).

Shapes follow the Mamba2 paper: x (B, L, H, P); B̄,C (B, L, G, S) shared
across H/G head groups; dt (B, L, H); A (H,) negative decay.

`ssd_chunked` is the production path; `ssd_scan_ref` is the token-by-token
recurrence oracle used in tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.layers import _dense_init, rmsnorm, init_rmsnorm
from repro.core.types import SSMSpec

Params = Dict[str, Any]


def ssd_scan_ref(x, dt, a, b_mat, c_mat, d_skip):
    """Sequential recurrence oracle.
    x: (B,L,H,P) dt: (B,L,H) a: (H,) b,c: (B,L,G,S) d: (H,).
    state s: (B,H,P,S); s_t = exp(dt*a) s_{t-1} + dt * x ⊗ b; y = s · c + d*x
    """
    bsz, l, h, p = x.shape
    g = b_mat.shape[2]
    rep = h // g
    bm = jnp.repeat(b_mat, rep, axis=2)  # (B,L,H,S)
    cm = jnp.repeat(c_mat, rep, axis=2)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,H,S) (B,H,S)
        decay = jnp.exp(dtt * a)[..., None, None]          # (B,H,1,1)
        upd = (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        s = s * decay + upd
        y = jnp.einsum("bhps,bhs->bhp", s, ct)
        return s, y

    s0 = jnp.zeros((bsz, h, p, b_mat.shape[-1]), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)                            # (B,L,H,P)
    return y + d_skip[None, None, :, None] * x.astype(jnp.float32)


def ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, chunk: int = 256):
    """Chunked SSD. Same output as ssd_scan_ref, O(L * chunk) intra work +
    O(L/chunk) sequential scan over chunk states."""
    bsz, l, h, p = x.shape
    g, s_dim = b_mat.shape[2], b_mat.shape[3]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // chunk
    rep = h // g

    xf = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtf = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bf = b_mat.reshape(bsz, nc, chunk, g, s_dim).astype(jnp.float32)
    cf = c_mat.reshape(bsz, nc, chunk, g, s_dim).astype(jnp.float32)
    bf = jnp.repeat(bf, rep, axis=3)                        # (B,N,Q,H,S)
    cf = jnp.repeat(cf, rep, axis=3)

    da = dtf * a                                            # (B,N,Q,H)
    cum = jnp.cumsum(da, axis=2)                            # inclusive
    seg_total = cum[:, :, -1]                               # (B,N,H)

    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) * (C_i·B_j) dt_j x_j
    dmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,N,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(dmat), 0.0)
    scores = jnp.einsum("bnqhs,bnkhs->bnqkh", cf, bf) * lmat
    xbar = xf * dtf[..., None]                              # (B,N,Q,H,P)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", scores, xbar)

    # chunk states: S_n = sum_j exp(seg_total - cum_j) dt_j B_j ⊗ x_j
    w = jnp.exp(seg_total[:, :, None] - cum)                # (B,N,Q,H)
    state_n = jnp.einsum("bnqh,bnqhs,bnqhp->bnhps", w, bf, xbar)

    # inter-chunk recurrence over N
    def step(s_prev, inp):
        st, tot = inp                                       # (B,H,P,S) (B,H)
        s_new = s_prev * jnp.exp(tot)[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, p, s_dim), jnp.float32)
    _, s_prevs = jax.lax.scan(
        step, s0, (state_n.transpose(1, 0, 2, 3, 4),
                   seg_total.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)              # (B,N,H,P,S)

    # inter-chunk contribution: y += exp(cum_i) * C_i · S_prev
    y_inter = jnp.einsum("bnqhs,bnhps->bnqhp", cf * jnp.exp(cum)[..., None],
                         s_prevs)
    y = (y_intra + y_inter).reshape(bsz, lp, h, p)[:, :l]
    return y + d_skip[None, None, :, None] * x.reshape(bsz, lp, h, p)[:, :l]


# ------------------------------------------------------------ full block ---

def init_mamba(key, d_model: int, spec: SSMSpec, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 6)
    di = spec.d_inner(d_model)
    h = spec.num_heads(d_model)
    g, s = spec.num_groups, spec.state_dim
    conv_dim = di + 2 * g * s
    return {
        "in_proj": _dense_init(ks[0], (d_model,
                                       2 * di + 2 * g * s + h), dtype=dtype),
        "conv_w": _dense_init(ks[1], (spec.conv_width, conv_dim),
                              scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))),
        "norm": init_rmsnorm(di),
        "out_proj": _dense_init(ks[4], (di, d_model), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """depthwise causal conv. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def mamba_block(params: Params, x, spec: SSMSpec, *, chunk: int = 256):
    """Full Mamba2 mixer. x: (B, L, Dm) -> (B, L, Dm)."""
    bsz, l, dm = x.shape
    di = spec.d_inner(dm)
    h = spec.num_heads(dm)
    g, s = spec.num_groups, spec.state_dim

    zxbcdt = x @ params["in_proj"]
    z, xin, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * s], -1)
    conv_in = jnp.concatenate([xin, bc], -1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
    xin, b_mat, c_mat = jnp.split(conv_out, [di, di + g * s], -1)
    xh = xin.reshape(bsz, l, h, spec.head_dim)
    b_mat = b_mat.reshape(bsz, l, g, s)
    c_mat = c_mat.reshape(bsz, l, g, s)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    y = ssd_chunked(xh, dt, a, b_mat, c_mat, params["d_skip"], chunk=chunk)
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"]


# ------------------------------------------------------------ decode -------

def init_mamba_cache(d_model: int, spec: SSMSpec, batch: int,
                     dtype=jnp.bfloat16):
    di = spec.d_inner(d_model)
    h = spec.num_heads(d_model)
    g, s = spec.num_groups, spec.state_dim
    conv_dim = di + 2 * g * s
    return {
        "conv": jnp.zeros((batch, spec.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, spec.head_dim, s), jnp.float32),
    }


def mamba_decode(params: Params, x, cache, spec: SSMSpec):
    """Single-token recurrent step. x: (B, 1, Dm). O(1) state — the SSM
    counterpart of the ring KV cache."""
    bsz, _, dm = x.shape
    di = spec.d_inner(dm)
    h = spec.num_heads(dm)
    g, s = spec.num_groups, spec.state_dim

    zxbcdt = x[:, 0] @ params["in_proj"]
    z, xin, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * s], -1)
    conv_in = jnp.concatenate([xin, bc], -1)                # (B, C)
    window = jnp.concatenate([cache["conv"],
                              conv_in[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xin, b_mat, c_mat = jnp.split(conv_out, [di, di + g * s], -1)
    xh = xin.reshape(bsz, h, spec.head_dim)
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, s), h // g, axis=1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, s), h // g, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    decay = jnp.exp(dt * a)[..., None, None]                # (B,H,1,1)
    upd = (dt[..., None] * xh)[..., None] * b_mat[:, :, None, :]
    ssm = cache["ssm"] * decay + upd
    y = jnp.einsum("bhps,bhs->bhp", ssm, c_mat)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z[:, None, :]))
    new_cache = {"conv": window[:, 1:], "ssm": ssm}
    return y @ params["out_proj"], new_cache
