"""Static block-sparsity patterns for window/global/random attention.

The paper sets sparsity (window width, global token indices, random token
indices) as *synthesis-time parameters* of the FPGA design. The TPU analogue
is a trace-time block pattern: for every q block we precompute (in numpy, on
host) the exact list of kv blocks it touches, and the kernels iterate only
those. This file is pure numpy — no jax — so patterns are computed once at
trace time and baked into kernel grids.

Slot kinds:
  PAD    - unused slot (rectangular grid padding), fully masked
  BAND   - sliding-window block, per-element band mask applied in-kernel
  GLOBAL - global-column block (first g tokens), only kv-bounds mask
  RANDOM - BigBird random block, only kv-bounds mask
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.types import AttentionSpec

PAD, BAND, GLOBAL, RANDOM = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: hash by identity
class BlockPattern:
    """Rectangular block-sparse schedule for one (seq_q, seq_kv) problem.

    kv_block_map : (num_q_blocks, num_slots) int32 - kv block index per slot
                   (0 where PAD; masked out by slot_kinds).
    slot_kinds   : (num_q_blocks, num_slots) int32 - PAD/BAND/GLOBAL/RANDOM.
    """

    spec: AttentionSpec
    seq_q: int
    seq_kv: int
    block_q: int
    block_kv: int
    kv_block_map: np.ndarray
    slot_kinds: np.ndarray

    @property
    def num_q_blocks(self) -> int:
        return self.kv_block_map.shape[0]

    @property
    def num_slots(self) -> int:
        return self.kv_block_map.shape[1]

    @property
    def num_kv_blocks(self) -> int:
        return _cdiv(self.seq_kv, self.block_kv)

    def active_fraction(self) -> float:
        """Fraction of the dense (q_blocks x kv_blocks) grid actually visited
        -- the block-level compute saving vs dense attention."""
        active = int((self.slot_kinds != PAD).sum())
        return active / float(self.num_q_blocks * self.num_kv_blocks)

    def inverse(self) -> "InversePattern":
        """For the dK/dV backward kernel: per kv block, which q blocks touch
        it. Pure numpy inversion of kv_block_map."""
        nkv = self.num_kv_blocks
        buckets = [[] for _ in range(nkv)]
        kinds = [[] for _ in range(nkv)]
        for i in range(self.num_q_blocks):
            for s in range(self.num_slots):
                k = int(self.slot_kinds[i, s])
                if k == PAD:
                    continue
                j = int(self.kv_block_map[i, s])
                buckets[j].append(i)
                kinds[j].append(k)
        width = max(1, max(len(b) for b in buckets))
        q_map = np.zeros((nkv, width), np.int32)
        q_kinds = np.full((nkv, width), PAD, np.int32)
        for j in range(nkv):
            q_map[j, : len(buckets[j])] = buckets[j]
            q_kinds[j, : len(kinds[j])] = kinds[j]
        return InversePattern(q_block_map=q_map, slot_kinds=q_kinds)


@dataclasses.dataclass(frozen=True, eq=False)
class InversePattern:
    q_block_map: np.ndarray   # (num_kv_blocks, num_q_slots)
    slot_kinds: np.ndarray    # (num_kv_blocks, num_q_slots)

    @property
    def num_slots(self) -> int:
        return self.q_block_map.shape[1]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def band_block_range(spec: AttentionSpec, q_block: int, block_q: int,
                     block_kv: int, seq_kv: int,
                     q_shift: int = 0) -> Tuple[int, int]:
    """Inclusive [lo, hi] kv-block range intersecting the band of q block i.

    q_shift: constant local-coordinate shift between q and kv rows (context
    parallelism: q row i aligns with LOCAL kv row i + q_shift because the kv
    buffer is prefixed by the halo received from the left neighbour)."""
    q_lo = q_block * block_q + q_shift
    q_hi = q_lo + block_q - 1
    lo_tok = max(0, q_lo - spec.window)
    hi_tok = q_hi if spec.causal else q_hi + spec.window
    hi_tok = min(seq_kv - 1, hi_tok)
    if lo_tok > hi_tok:  # band entirely outside this kv buffer
        return 0, -1
    return lo_tok // block_kv, min(hi_tok // block_kv,
                                   _cdiv(seq_kv, block_kv) - 1)


def build_block_pattern(spec: AttentionSpec, seq_q: int, seq_kv: int,
                        block_q: int, block_kv: int,
                        q_shift: int = 0) -> BlockPattern:
    """Compute the rectangular block schedule for `spec`.

    Dense specs get the full kv range (the same kernels then implement vanilla
    flash attention -- used for the paper's dense baseline and for gemma2
    global layers)."""
    nq = _cdiv(seq_q, block_q)
    nkv = _cdiv(seq_kv, block_kv)

    if not spec.is_sparse:
        if spec.causal and seq_q == seq_kv:
            rows = []
            for i in range(nq):
                hi = ((i + 1) * block_q - 1) // block_kv
                rows.append([(j, BAND) for j in range(min(hi, nkv - 1) + 1)])
        else:
            rows = [[(j, GLOBAL) for j in range(nkv)] for _ in range(nq)]
        return _pack(spec, seq_q, seq_kv, block_q, block_kv, rows)

    n_global_blocks = _cdiv(spec.num_global, block_kv) if spec.num_global else 0
    rng = np.random.RandomState(spec.random_seed)

    rows = []
    for i in range(nq):
        slots = []
        taken = set()
        # global columns first (paper: dedicated pinned attention cores)
        for j in range(min(n_global_blocks, nkv)):
            slots.append((j, GLOBAL))
            taken.add(j)
        lo, hi = band_block_range(spec, i, block_q, block_kv, seq_kv, q_shift)
        for j in range(lo, hi + 1):
            if j not in taken:
                slots.append((j, BAND))
                taken.add(j)
        if spec.num_random:
            candidates = [j for j in range(nkv) if j not in taken]
            if spec.causal:  # random blocks must stay in the visible prefix
                hi_vis = ((i + 1) * block_q - 1) // block_kv
                candidates = [j for j in candidates if j <= hi_vis]
            rng_pick = rng.permutation(len(candidates))[: spec.num_random]
            for idx in sorted(rng_pick):
                slots.append((candidates[idx], RANDOM))
        rows.append(slots)
    return _pack(spec, seq_q, seq_kv, block_q, block_kv, rows)


def _pack(spec, seq_q, seq_kv, block_q, block_kv, rows) -> BlockPattern:
    num_slots = max(len(r) for r in rows)
    nq = len(rows)
    kv_map = np.zeros((nq, num_slots), np.int32)
    kinds = np.full((nq, num_slots), PAD, np.int32)
    for i, r in enumerate(rows):
        for s, (j, kind) in enumerate(r):
            kv_map[i, s] = j
            kinds[i, s] = kind
    return BlockPattern(spec=spec, seq_q=seq_q, seq_kv=seq_kv,
                        block_q=block_q, block_kv=block_kv,
                        kv_block_map=kv_map, slot_kinds=kinds)


def dense_mask(spec: AttentionSpec, seq_q: int, seq_kv: int,
               q_offset: int = 0) -> np.ndarray:
    """O(N^2) boolean mask — the oracle the kernels are tested against.
    mask[i, j] True where q token (i + q_offset) may attend kv token j."""
    i = np.arange(seq_q)[:, None] + q_offset
    j = np.arange(seq_kv)[None, :]
    if not spec.is_sparse:
        return (j <= i) if spec.causal else np.ones((seq_q, seq_kv), bool)
    band = (j >= i - spec.window)
    if not spec.causal:
        band = band & (j <= i + spec.window)
    m = band
    if spec.num_global:
        g = spec.num_global
        m = m | (j < g) | (i < g)  # global cols + global rows
    if spec.causal:
        m = m & (j <= i)
    return m


def random_blocks_mask(pattern: BlockPattern) -> np.ndarray:
    """Adds the pattern's RANDOM blocks to dense_mask (block granularity is
    part of the spec, so the oracle derives it from the pattern itself)."""
    m = dense_mask(pattern.spec, pattern.seq_q, pattern.seq_kv)
    bq, bk = pattern.block_q, pattern.block_kv
    i_tok = np.arange(pattern.seq_q)[:, None]
    for i in range(pattern.num_q_blocks):
        for s in range(pattern.num_slots):
            if pattern.slot_kinds[i, s] == RANDOM:
                j = pattern.kv_block_map[i, s]
                rows = slice(i * bq, min((i + 1) * bq, pattern.seq_q))
                cols = slice(j * bk, min((j + 1) * bk, pattern.seq_kv))
                blk = np.ones((rows.stop - rows.start, cols.stop - cols.start),
                              bool)
                if pattern.spec.causal:
                    blk &= (np.arange(cols.start, cols.stop)[None, :]
                            <= i_tok[rows, :])
                m[rows, cols] |= blk
    return m


def sliding_chunks_flops_ratio(seq_len: int, window: int) -> float:
    """Paper §1: redundant-FLOP ratio of the sliding-chunks baseline,
    1/2 - 1/(4|chunks|). Used by benchmarks/fig2."""
    n_chunks = max(1, seq_len // (2 * window))
    return 0.5 - 1.0 / (4.0 * n_chunks)
