"""Neural net layers: norms, rotary embeddings, GQA attention, MLP.

Parameters are plain pytrees (dicts of jnp arrays); every layer has an
`init_*` returning params and an `apply`-style function. No framework
dependency — keeps scan-over-layers and sharding rules transparent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import AttentionSpec, ModelConfig
from repro.kernels import ops as kops

Params = Dict[str, Any]


def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1+scale) parametrization


def rmsnorm(params: Params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dtype)


# ----------------------------------------------------------------- rope ----

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., L, D) with positions (..., L) or (L,). Rotates pairs
    (x[2i], x[2i+1]) — llama convention (split-half)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((length, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ------------------------------------------------------- sharding helper ---

def with_activation_constraint(x, sharding):
    """Pin activations to a sharding at super-block boundaries (training SP
    layout, or the serving decode/prefill batch layout). `sharding` is a
    NamedSharding / PartitionSpec, or None for a no-op — call sites stay
    unconditional so the model code reads the same sharded and not."""
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# ------------------------------------------------------------ attention ----

@dataclasses.dataclass(frozen=True)
class AttentionLayerCfg:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    spec: AttentionSpec
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    cross: bool = False          # cross-attention (whisper decoder)


def init_attention(key, cfg: AttentionLayerCfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    dm, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (dm, hq * dh), dtype=dtype),
        "wk": _dense_init(ks[1], (dm, hkv * dh), dtype=dtype),
        "wv": _dense_init(ks[2], (dm, hkv * dh), dtype=dtype),
        "wo": _dense_init(ks[3], (hq * dh, dm), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(params, cfg: AttentionLayerCfg, x, kv_x):
    b, l, _ = x.shape
    lkv = kv_x.shape[1]
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, l, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, lkv, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, lkv, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attention_layer(params: Params, cfg: AttentionLayerCfg, x, *,
                    kv_x=None, positions=None, impl: str = "xla") -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, L, Dm)."""
    b, l, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    if cfg.use_rope and not cfg.cross:
        pos = (jnp.arange(l) if positions is None else positions)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = kops.swat_attention(q, k, v, cfg.spec, impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return out @ params["wo"]


# KV cache ------------------------------------------------------------------

def _round_capacity(cap: int) -> int:
    """Round a ring ALLOCATION up to a TPU-friendly quantum so the
    swat_decode kernel can tile the cache exactly (block_kv | cap) instead
    of jnp.pad-ing — and copying — the WHOLE cache on every decode step.
    Small rings round to the bf16 sublane tile (16); larger ones to 64 so
    the kernel keeps a wide KV block. A few extra zero rows cost O(window)
    bytes once; the pad cost a full cache copy per token per layer."""
    q = 64 if cap > 64 else 16
    return -(-cap // q) * q


def cache_capacity(cfg: AttentionLayerCfg, max_len: int,
                   lookahead: int = 0) -> int:
    """LOGICAL ring capacity: window+1(+lookahead)(+globals) for causal
    sparse attention (the paper's FIFO — decode attends at most window+1
    rows, never more), full context for dense. `lookahead` adds extra ring
    rows so a T-token decode step (T <= lookahead+1) never overwrites a
    token still inside some query's window — the multi-token/speculative
    allocation knob; the attention window itself is unchanged (positional
    masking in `decode_attention` hides the extra depth). `max_len` may be
    a physical allocation width (`cache["k"].shape[2]`): the logical
    capacity is recoverable from it (with the same lookahead) because
    allocations are only ever >= logical (tile rounding).

    Like `max_len`, `lookahead` is part of the cache GEOMETRY: the same
    value must reach init_kv_cache/prefill/chunk/decode for one cache (a
    mismatch rotates at the wrong modulus — it cannot live in the cache
    dict because the kernel needs it static under jit). The engine threads
    it everywhere from one knob, `ServingEngine(tokens_per_step=)`."""
    if cfg.spec.is_sparse:
        cap = cfg.spec.window + 1 + lookahead + cfg.spec.num_global
        return min(cap, max_len)
    return max_len


def cache_allocation(cfg: AttentionLayerCfg, max_len: int,
                     lookahead: int = 0) -> int:
    """PHYSICAL rows allocated for the ring: the logical capacity rounded up
    to a tile quantum (clamped to max_len). Rows in [logical, physical) are
    never written and never attended (`cache_len` <= logical masks them) —
    they exist purely so the decode kernel's grid tiles the cache exactly
    and the hot path never re-pads. Window semantics are untouched: the
    rotation modulus stays the logical capacity."""
    cap = cache_capacity(cfg, max_len, lookahead)
    if cfg.spec.is_sparse:
        return min(_round_capacity(cap), max_len)
    return cap


def init_kv_cache(cfg: AttentionLayerCfg, batch: int, max_len: int,
                  dtype=jnp.bfloat16, lookahead: int = 0):
    """Ring KV cache with a PER-SLOT write pointer: `step` is (batch,) so a
    continuously-batched decode can serve slots at different depths from one
    kernel call (each row inserts at its own ring position). Allocated at
    `cache_allocation` width (tile-rounded; the tail rows past the logical
    capacity stay zero and masked forever). lookahead: extra ring rows for
    T-token decode steps (`cache_capacity`)."""
    cap = cache_allocation(cfg, max_len, lookahead)
    shape = (batch, cfg.num_kv_heads, cap, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "step": jnp.zeros((batch,), jnp.int32)}


# Paged KV ------------------------------------------------------------------
#
# The paged layout splits each layer's ring allocation into fixed PAGE_SIZE-
# row blocks living in a pool, addressed through a per-slot block table:
#   shared pool (single-device):  pk/pv (P, H, page, D), table (B, nb) holds
#       GLOBAL block ids — slots may reference the same block (prefix
#       sharing, refcounted copy-on-write on the host side).
#   local pool (under a mesh):    pk/pv (B, nb+1, H, page, D), table (B, nb)
#       holds LOCAL ids — no cross-slot references, so the pool shards over
#       the slot axis and decode stays collective-free (gather/scatter are
#       one-hot selects, the `_dyn_update` trick, never dynamic gathers).
# Both carry one spare block per slot (the scratch block): freed slots park
# their whole table on it so the decode scan's unconditional ring writes for
# dead rows land somewhere never read.
#
# Decode gathers the table into a contiguous (B, H, nb*page, D) view, runs
# the UNCHANGED ring attention (same kernel, same tiling, same masks — the
# view width equals the contiguous allocation exactly, which is why
# PAGE_SIZE divides every `cache_allocation`), and scatters the whole view
# back. Bitwise identity with the contiguous engine falls out by
# construction; the cost is a pool-sized copy per step, the same O(cache)
# traffic the `_dyn_update` select already pays.

PAGE_SIZE = 16   # rows per block == the bf16 sublane tile `_round_capacity`
                 # rounds to, so every ring allocation is block-divisible


def paged_num_blocks(cfg: AttentionLayerCfg, max_len: int,
                     lookahead: int = 0, page: int = PAGE_SIZE) -> int:
    """Blocks per slot for one layer's ring. The allocation must tile
    exactly — true for every sparse ring (`_round_capacity` quantum 16/64)
    and for dense caches whenever max_len is a multiple of 16."""
    alloc = cache_allocation(cfg, max_len, lookahead)
    if alloc % page:
        raise ValueError(
            f"paged KV needs page-divisible allocations: {alloc} rows "
            f"% {page} != 0 (pick max_len a multiple of {page})")
    return alloc // page


def init_paged_kv_cache(cfg: AttentionLayerCfg, batch: int, max_len: int,
                        dtype=jnp.bfloat16, lookahead: int = 0,
                        page: int = PAGE_SIZE, shared_pool: bool = True):
    """Paged twin of `init_kv_cache`. shared_pool picks the global-id layout
    (block sharing possible) vs the slot-local layout (mesh-shardable).
    Tables start at the identity mapping: slot s owns its home blocks, so a
    freshly-initialized paged cache gathers to exactly `init_kv_cache`'s
    zeros."""
    nb = paged_num_blocks(cfg, max_len, lookahead, page)
    nbp = nb + 1                                   # + per-slot scratch block
    hkv, d = cfg.num_kv_heads, cfg.head_dim
    if shared_pool:
        shape = (batch * nbp, hkv, page, d)
        table = (jnp.arange(batch, dtype=jnp.int32)[:, None] * nbp
                 + jnp.arange(nb, dtype=jnp.int32)[None, :])
    else:
        shape = (batch, nbp, hkv, page, d)
        table = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32),
                                 (batch, nb)).astype(jnp.int32)
    return {"pk": jnp.zeros(shape, dtype), "pv": jnp.zeros(shape, dtype),
            "table": table, "step": jnp.zeros((batch,), jnp.int32)}


def paged_gather(pool, table):
    """Contiguous (B, H, nb*page, D) view of each slot's blocks."""
    from repro.kernels import swat_decode as _sd
    _sd.record_paged_fallback(
        table.shape[-1], pool.shape[-2],
        "table resolved outside the kernel: materialized gather-view "
        "decode (pool-sized copy per step) instead of in-kernel gather")
    if pool.ndim == 4:    # shared pool (P, H, page, D), global ids
        blocks = pool[table]                            # (B, nb, H, page, D)
    else:                 # local pool (B, nbp, H, page, D), local ids
        nbp = pool.shape[1]
        hot = (table[..., None]
               == jnp.arange(nbp, dtype=jnp.int32))     # (B, nb, nbp)
        # one-hot select, not a gather: exact in any dtype (one nonzero
        # term per output) and partitionable under slot sharding
        blocks = jnp.einsum("bnp,bphkd->bnhkd", hot.astype(pool.dtype), pool)
    b, nb, h, page, d = blocks.shape
    return blocks.transpose(0, 2, 1, 3, 4).reshape(b, h, nb * page, d)


def paged_scatter(pool, table, view):
    """Write a (B, H, nb*page, D) contiguous view back through the table.
    Duplicate table entries (shared blocks, parked scratch rows) receive
    value-identical or never-read writes, so scatter order is immaterial."""
    b, h, rows, d = view.shape
    nb = table.shape[-1]
    page = rows // nb
    blocks = (view.reshape(b, h, nb, page, d)
              .transpose(0, 2, 1, 3, 4).astype(pool.dtype))  # (B,nb,H,pg,D)
    if pool.ndim == 4:
        return pool.at[table].set(blocks)
    nbp = pool.shape[1]
    hot = (table[..., None] == jnp.arange(nbp, dtype=jnp.int32))  # (B,nb,nbp)
    upd = jnp.einsum("bnp,bnhkd->bphkd", hot.astype(pool.dtype), blocks)
    written = jnp.any(hot, axis=1)                                # (B, nbp)
    return jnp.where(written[:, :, None, None, None], upd, pool)


def attention_decode(params: Params, cfg: AttentionLayerCfg, x, cache, *,
                     impl: str = "ref", lookahead: int = 0):
    """T-token decode. x: (B, T, Dm). Ring insertion at (step mod cap) for
    sparse specs — the paper's FIFO replacement policy (row index mod window)
    — happens INSIDE the attention call (`decode_attention(new_kv=)`): the
    pallas impl writes the new rows into the cache block it already holds in
    VMEM (input-stationary, Fig. 4b) instead of paying a separate scatter
    dispatch and full-cache HBM round trip per layer per token; the ref impl
    scatters-then-attends with identical masks (the parity oracle). Global
    tokens occupy pinned slots [0, g) (paper §4.1's fixed K/V buffers); the
    ring occupies [g, cap). `step` is per-slot (B,): every row rotates,
    ropes, and masks at its own depth, which is what lets one batched call
    serve slots mid-flight at different positions. T > 1 (the speculative-
    decode verify primitive) needs a cache allocated with lookahead >= T-1
    so the step's own inserts never evict an in-window token."""
    b, t, _ = x.shape
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    step = jnp.broadcast_to(jnp.asarray(cache["step"], jnp.int32), (b,))
    if cfg.use_rope and not cfg.cross:
        pos = step[:, None, None] + jnp.arange(t, dtype=jnp.int32)  # (B,1,T)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    paged = "table" in cache
    if paged:
        # contiguous view of the block table; width == the contiguous
        # allocation, so everything below is bit-for-bit the ring path
        k_arr = paged_gather(cache["pk"], cache["table"])
        v_arr = paged_gather(cache["pv"], cache["table"])
    else:
        k_arr, v_arr = cache["k"], cache["v"]
    # rotate and mask at the LOGICAL capacity: the allocation may carry a
    # tile-rounding tail of zero rows that must never be written or attended
    # (otherwise the rounding would silently widen the attention window)
    cap = cache_capacity(cfg, k_arr.shape[2], lookahead)
    g = cfg.spec.num_global if cfg.spec.is_sparse else 0
    assert t == 1 or not cfg.spec.is_sparse \
        or cap - g >= cfg.spec.window + t, (
            f"T={t} decode on a {cap - g}-row ring would evict in-window "
            "tokens: allocate caches with lookahead >= T-1")
    out, k_cache, v_cache = kops.decode_attention(
        q, k_arr, v_arr, None, cfg.spec, impl=impl,
        new_kv=(k_new, v_new), pos=step, ring_cap=cap)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
    if paged:
        new_cache = {**cache,
                     "pk": paged_scatter(cache["pk"], cache["table"],
                                         k_cache),
                     "pv": paged_scatter(cache["pv"], cache["table"],
                                         v_cache),
                     "step": step + t}
    else:
        new_cache = {"k": k_cache, "v": v_cache, "step": step + t}
    return out @ params["wo"], new_cache


def _dyn_update(cache, new, slot):
    """Insert one row per batch element at its own dynamic `slot` along the
    cap axis.

    Implemented as iota==slot select, NOT dynamic_update_slice: a scatter at
    a dynamic index across a sequence-sharded cache forces XLA SPMD into
    "involuntary full rematerialization" (it replicates the whole cache
    every step). The select partitions trivially under any cap sharding at
    the cost of a full-cache write — decode already reads the full cache for
    attention, so the added traffic is bounded at ~1.5x and the collective
    catastrophe is gone (see EXPERIMENTS.md §Perf).
    cache: (B, H, cap, D); new: (B, H, 1, D); slot: (B,) or scalar int32."""
    b, _, cap, _ = cache.shape
    slot = jnp.broadcast_to(jnp.asarray(slot, jnp.int32), (b,))
    hit = (jnp.arange(cap, dtype=jnp.int32)[None, :]
           == slot[:, None])[:, None, :, None]
    return jnp.where(hit, new.astype(cache.dtype), cache)


def ring_scatter(cache_kv, new, positions, write, g: int, ring):
    """Write `new` (B, H, T, D) rows into their ring slots of a cache
    (B, H, cap, D). positions: (T,) absolute token indices (traced ok, shared
    across rows); write: (B, T) bool — which tokens are real for each row
    (right-padded rows just stop writing). Per (row, slot) the highest-index
    writer wins, so a span longer than the ring and per-row ragged lengths
    both resolve exactly as sequential FIFO insertion would."""
    b, _, cap, _ = cache_kv.shape
    t = new.shape[2]
    positions = jnp.asarray(positions, jnp.int32)
    slot = jnp.where(positions < g, positions, g + (positions - g) % ring)
    jidx = jnp.arange(t, dtype=jnp.int32)
    hit = slot[:, None] == jnp.arange(cap, dtype=jnp.int32)[None, :]  # (T,cap)
    cand = jnp.where(write[:, :, None] & hit[None], jidx[None, :, None], -1)
    winner = jnp.max(cand, axis=1)                                    # (B,cap)
    sel = jidx[None, :, None] == winner[:, None, :]                   # (B,T,cap)
    upd = jnp.einsum("bjs,bhjd->bhsd", sel.astype(cache_kv.dtype),
                     new.astype(cache_kv.dtype))
    return jnp.where((winner >= 0)[:, None, :, None], upd, cache_kv)


def prefill_kv_cache(params: Params, cfg: AttentionLayerCfg, x, max_len: int,
                     positions=None, lengths=None, lookahead: int = 0):
    """Fill a cache from a prompt (B, L, Dm). For ring caches only the last
    `cap` tokens are retained (earlier ones are outside every future window).

    lengths: optional (B,) int32 — per-row real prompt length for a padded
    batched prefill. Rows write only their first `lengths[i]` tokens and the
    cache step is set per row, so decode continues each row at its own
    position. Without it every row is taken at full length L."""
    b, l, _ = x.shape
    _, k, v = _project_qkv(params, cfg, x, x)
    if cfg.use_rope and not cfg.cross:
        pos = jnp.arange(l) if positions is None else positions
        k = apply_rope(k, pos, cfg.rope_theta)
    cap = cache_capacity(cfg, max_len, lookahead)
    cache = init_kv_cache(cfg, b, max_len, dtype=k.dtype,
                          lookahead=lookahead)
    g = cfg.spec.num_global if cfg.spec.is_sparse else 0
    if l <= cap:
        # no wrap possible: natural slots. Rows shorter than L carry pad K/V
        # above their step, permanently masked (valid = [0, min(step, cap)))
        # and overwritten one-for-one as decode advances.
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, 0, 0))
    else:
        # pinned globals + ring tail, laid out to match attention_decode;
        # last-writer-wins scatter reproduces sequential FIFO insertion
        # per row even when rows wrap at different lengths.
        lens = (jnp.full((b,), l, jnp.int32) if lengths is None
                else jnp.asarray(lengths, jnp.int32))
        write = jnp.arange(l, dtype=jnp.int32)[None, :] < lens[:, None]
        cache["k"] = ring_scatter(cache["k"], k, jnp.arange(l), write,
                                  g, cap - g)
        cache["v"] = ring_scatter(cache["v"], v, jnp.arange(l), write,
                                  g, cap - g)
    cache["step"] = (jnp.full((b,), l, jnp.int32) if lengths is None
                     else jnp.asarray(lengths, jnp.int32))
    return cache


def attention_prefill_chunk(params: Params, cfg: AttentionLayerCfg, x, cache,
                            pos0, lengths, lookahead: int = 0):
    """One chunk of a batched chunked prefill: attend tokens [pos0, pos0+T)
    against the ring cache (all earlier chunks) plus the chunk itself, then
    append the chunk's K/V to the ring.

    This is exact — the ring holds every token a band query can still see
    (window + pinned globals), so chunked prefill computes the same function
    as full-sequence prefill while the score matrix stays (T, cap+T): VMEM
    is bounded by the chunk size, not the prompt length. Causal specs only.

    pos0 may be a traced scalar (shared by all rows — the scheduler chunks
    the padded batch in lockstep); per-row raggedness comes from `lengths`:
    rows stop writing past their own length and their surplus outputs are
    garbage the caller discards. Returns (output (B, T, Dm), new cache)."""
    assert cfg.spec.causal and not cfg.cross
    b, t, _ = x.shape
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    pos = pos0 + jnp.arange(t, dtype=jnp.int32)            # (T,) absolute
    if cfg.use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    cap_phys = cache["k"].shape[2]
    cap = cache_capacity(cfg, cap_phys, lookahead)  # logical: rot. modulus
    g = cfg.spec.num_global if cfg.spec.is_sparse else 0
    ring = cap - g
    w = cfg.spec.window if cfg.spec.is_sparse else cap + t  # dense: no band
    lens = jnp.asarray(lengths, jnp.int32)

    # which token each cache slot holds just before this chunk: pinned slot
    # s holds token s; ring slot r holds the latest token < pos0 congruent
    # to r (all traced arithmetic so pos0 never forces a retrace). Slots in
    # the tile-rounding tail [cap, cap_phys) are never occupied.
    s_idx = jnp.arange(cap_phys, dtype=jnp.int32)
    r = s_idx - g
    t_ring = (pos0 - 1) - jnp.mod((pos0 - 1 - g) - r, ring)
    slot_pos = jnp.where(s_idx < g, s_idx, t_ring)
    occupied = jnp.where(s_idx < g, pos0 > s_idx,
                         (pos0 > g + r) & (t_ring >= g)) & (s_idx < cap)
    live = occupied[None, :] & (slot_pos[None, :] < lens[:, None])  # (B,cap)

    # band/global masks (causality vs cache is automatic: slot_pos < pos0)
    allow_c = ((s_idx[None, :] < g)
               | (slot_pos[None, :] >= pos[:, None] - w)
               | (pos[:, None] < g))                       # (T, cap)
    mask_c = live[:, None, :] & allow_c[None]              # (B, T, cap)
    mask_s = ((pos[None, :] <= pos[:, None])
              & ((pos[None, :] >= pos[:, None] - w)
                 | (pos[None, :] < g) | (pos[:, None] < g)))  # (T, T)

    group = cfg.num_heads // cfg.num_kv_heads
    d = cfg.head_dim
    from repro.kernels import dots
    qg = (q.reshape(b, cfg.num_kv_heads, group, t, d)
          * jnp.asarray(d ** -0.5, q.dtype))
    s_c = dots.einsum_f32("bhgtd,bhcd->bhgtc", qg, cache["k"])
    s_s = dots.einsum_f32("bhgtd,bhkd->bhgtk", qg, k_new)
    if cfg.spec.softcap:
        s_c = cfg.spec.softcap * jnp.tanh(s_c / cfg.spec.softcap)
        s_s = cfg.spec.softcap * jnp.tanh(s_s / cfg.spec.softcap)
    s_c = jnp.where(mask_c[:, None, None], s_c, kops.NEG_INF)
    s_s = jnp.where(mask_s[None, None, None], s_s, kops.NEG_INF)
    s_all = jnp.concatenate([s_c, s_s], axis=-1)
    m = jax.lax.stop_gradient(jnp.max(s_all, axis=-1, keepdims=True))
    p = jnp.exp(s_all - m)
    p = jnp.where(jnp.concatenate(
        [jnp.broadcast_to(mask_c[:, None, None], s_c.shape),
         jnp.broadcast_to(mask_s[None, None, None], s_s.shape)], axis=-1),
        p, 0.0)
    den = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    v_all = jnp.concatenate([cache["v"], v_new], axis=2)
    o = dots.einsum_f32("bhgtk,bhkd->bhgtd", (p / den).astype(v_all.dtype),
                        v_all)
    out = (o.reshape(b, cfg.num_heads, t, d).astype(x.dtype)
           .transpose(0, 2, 1, 3).reshape(b, t, -1))

    write = pos[None, :] < lens[:, None]                   # (B, T)
    new_cache = {
        **cache,
        "k": ring_scatter(cache["k"], k_new, pos, write, g, ring),
        "v": ring_scatter(cache["v"], v_new, pos, write, g, ring),
        "step": jnp.minimum(lens, pos0 + t).astype(jnp.int32),
    }
    return out @ params["wo"], new_cache


# ---------------------------------------------------------------- mlp ------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w1": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
         "w2": _dense_init(ks[1], (d_ff, d_model), dtype=dtype)}
    if gated:
        p["w3"] = _dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp(params: Params, x, activation: str = "silu"):
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(x @ params["w1"])
    if "w3" in params:
        h = h * (x @ params["w3"])
    return h @ params["w2"]


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
