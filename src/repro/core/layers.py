"""Neural net layers: norms, rotary embeddings, GQA attention, MLP.

Parameters are plain pytrees (dicts of jnp arrays); every layer has an
`init_*` returning params and an `apply`-style function. No framework
dependency — keeps scan-over-layers and sharding rules transparent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import AttentionSpec, ModelConfig
from repro.kernels import ops as kops

Params = Dict[str, Any]


def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1+scale) parametrization


def rmsnorm(params: Params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dtype)


# ----------------------------------------------------------------- rope ----

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., L, D) with positions (..., L) or (L,). Rotates pairs
    (x[2i], x[2i+1]) — llama convention (split-half)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((length, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ------------------------------------------------------------ attention ----

@dataclasses.dataclass(frozen=True)
class AttentionLayerCfg:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    spec: AttentionSpec
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    cross: bool = False          # cross-attention (whisper decoder)


def init_attention(key, cfg: AttentionLayerCfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    dm, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (dm, hq * dh), dtype=dtype),
        "wk": _dense_init(ks[1], (dm, hkv * dh), dtype=dtype),
        "wv": _dense_init(ks[2], (dm, hkv * dh), dtype=dtype),
        "wo": _dense_init(ks[3], (hq * dh, dm), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(params, cfg: AttentionLayerCfg, x, kv_x):
    b, l, _ = x.shape
    lkv = kv_x.shape[1]
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, l, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, lkv, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, lkv, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attention_layer(params: Params, cfg: AttentionLayerCfg, x, *,
                    kv_x=None, positions=None, impl: str = "xla") -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, L, Dm)."""
    b, l, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    if cfg.use_rope and not cfg.cross:
        pos = (jnp.arange(l) if positions is None else positions)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = kops.swat_attention(q, k, v, cfg.spec, impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return out @ params["wo"]


# KV cache ------------------------------------------------------------------

def cache_capacity(cfg: AttentionLayerCfg, max_len: int) -> int:
    """Ring capacity: window+1 for causal sparse attention (the paper's FIFO),
    full context for dense."""
    if cfg.spec.is_sparse:
        cap = cfg.spec.window + 1 + cfg.spec.num_global
        return min(cap, max_len)
    return max_len


def init_kv_cache(cfg: AttentionLayerCfg, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    cap = cache_capacity(cfg, max_len)
    shape = (batch, cfg.num_kv_heads, cap, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "step": jnp.zeros((), jnp.int32)}


def attention_decode(params: Params, cfg: AttentionLayerCfg, x, cache, *,
                     impl: str = "xla"):
    """One-token decode. x: (B, 1, Dm). Ring insertion at (step mod cap) for
    sparse specs — the paper's FIFO replacement policy (row index mod window).
    Global tokens occupy pinned slots [0, g) (paper §4.1's fixed K/V buffers);
    the ring occupies [g, cap)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    step = cache["step"]
    if cfg.use_rope and not cfg.cross:
        pos = jnp.full((1,), step, jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    cap = cache["k"].shape[2]
    g = cfg.spec.num_global if cfg.spec.is_sparse else 0
    ring = cap - g
    slot = jnp.where(step < g, step, g + (step - g) % ring)
    k_cache = _dyn_update(cache["k"], k_new, slot)
    v_cache = _dyn_update(cache["v"], v_new, slot)
    cache_len = jnp.minimum(step + 1, cap)
    out = kops.decode_attention(q, k_cache, v_cache,
                                cache_len[None, None, None, None]
                                * jnp.ones((b, 1, 1, 1), jnp.int32),
                                cfg.spec)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    new_cache = {"k": k_cache, "v": v_cache, "step": step + 1}
    return out @ params["wo"], new_cache


def _dyn_update(cache, new, slot):
    """Insert one row at dynamic `slot` along the cap axis.

    Implemented as iota==slot select, NOT dynamic_update_slice: a scatter at
    a dynamic index across a sequence-sharded cache forces XLA SPMD into
    "involuntary full rematerialization" (it replicates the whole cache
    every step). The select partitions trivially under any cap sharding at
    the cost of a full-cache write — decode already reads the full cache for
    attention, so the added traffic is bounded at ~1.5x and the collective
    catastrophe is gone (see EXPERIMENTS.md §Perf).
    cache: (B, H, cap, D); new: (B, H, 1, D); slot: scalar int32."""
    cap = cache.shape[2]
    hit = (jnp.arange(cap, dtype=jnp.int32)
           == slot.astype(jnp.int32))[None, None, :, None]
    return jnp.where(hit, new.astype(cache.dtype), cache)


def prefill_kv_cache(params: Params, cfg: AttentionLayerCfg, x, max_len: int,
                     positions=None):
    """Fill a cache from a prompt (B, L, Dm). For ring caches only the last
    `cap` tokens are retained (earlier ones are outside every future window)."""
    b, l, _ = x.shape
    _, k, v = _project_qkv(params, cfg, x, x)
    if cfg.use_rope and not cfg.cross:
        pos = jnp.arange(l) if positions is None else positions
        k = apply_rope(k, pos, cfg.rope_theta)
    cap = cache_capacity(cfg, max_len)
    cache = init_kv_cache(cfg, b, max_len, dtype=k.dtype)
    g = cfg.spec.num_global if cfg.spec.is_sparse else 0
    if l <= cap:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, 0, 0))
    else:
        # pinned globals + ring tail, laid out to match attention_decode
        ring = cap - g
        start = l - ring
        ks = jnp.concatenate([k[:, :, :g], _ring_tail(k, start, ring, g)], 2)
        vs = jnp.concatenate([v[:, :, :g], _ring_tail(v, start, ring, g)], 2)
        cache["k"], cache["v"] = ks, vs
    cache["step"] = jnp.asarray(l, jnp.int32)
    return cache


def _ring_tail(k, start, ring, g):
    """Last `ring` rows placed at their ring slots (slot = g+(i-g) % ring)."""
    tail = jax.lax.dynamic_slice_in_dim(k, start, ring, axis=2)
    # token index of tail[j] is start+j; its slot is (start+j-g) % ring
    idx = (start + jnp.arange(ring) - g) % ring
    return jnp.zeros_like(tail).at[:, :, idx].set(tail)


# ---------------------------------------------------------------- mlp ------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w1": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
         "w2": _dense_init(ks[1], (d_ff, d_model), dtype=dtype)}
    if gated:
        p["w3"] = _dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp(params: Params, x, activation: str = "silu"):
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(x @ params["w1"])
    if "w3" in params:
        h = h * (x @ params["w3"])
    return h @ params["w2"]


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
