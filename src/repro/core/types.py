"""Core configuration dataclasses shared by every layer of the framework.

Everything here is hashable/static so configs can be closed over by jit
without retracing surprises.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Static sparse attention pattern (the paper's design-time parameters).

    kind:
      dense           - vanilla softmax attention (the paper's GPU baseline)
      swat            - exact-band window attention (the paper's contribution)
      sliding_chunks  - HuggingFace Longformer chunked baseline (~50% redundant)
    window          - w. each token attends [i-w, i+w] (bidirectional) or
                      [i-w, i] (causal). 0 means no band restriction.
    num_global      - first g tokens are global (attend all / attended by all),
                      Longformer-style.
    num_random      - random *blocks* each q-block additionally attends
                      (BigBird-style, static at trace time from random_seed).
    causal          - decoder-style masking.
    softcap         - gemma2-style logit soft capping (0 = off).
    """

    kind: str = "dense"
    window: int = 0
    num_global: int = 0
    num_random: int = 0
    random_seed: int = 0
    causal: bool = True
    softcap: float = 0.0

    def __post_init__(self):
        assert self.kind in ("dense", "swat", "sliding_chunks"), self.kind
        if self.kind != "dense":
            assert self.window > 0, "sparse attention needs a window"

    @property
    def is_sparse(self) -> bool:
        return self.kind != "dense"

    def flops_per_row(self, seq_len: int, head_dim: int) -> float:
        """Attention matmul FLOPs for one query row (one head), for
        benchmarks/fig1. 2*D per score + 2*D per value-accumulate."""
        if self.kind == "dense":
            cols = seq_len
        elif self.kind == "swat":
            cols = min(seq_len, (self.window + 1) if self.causal
                       else (2 * self.window + 1))
            cols += min(self.num_global, seq_len)
        else:  # sliding_chunks: dense 2w x 2w chunks with 50% redundancy
            cols = min(seq_len, 2 * self.window) * 2
        return 4.0 * cols * head_dim


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """dispatch:
      sort  - capacity + sort/scatter dispatch, EP all-to-all (the classic
              big-E MoE schedule; right when k/E is small).
      dense - tokens stationary, every expert computed locally, combined by
              the (renormalized) top-k gates. Costs E/k x active FFN FLOPs
              but ZERO dispatch collectives and no capacity drops — strictly
              better when E/k is small and the cell is collective-bound
              (granite-moe: E/k = 4; see EXPERIMENTS.md §Perf cell 1).
      ep    - explicit expert parallelism: shard_map token exchange with two
              all-to-alls over 'model' (core/moe_ep.py). Wire bytes scale
              with LOCAL tokens only; the schedule production MoE systems
              use. Right when E/k is large (moonshot 64/6, jamba 16/2).
    """
    num_experts: int = 0
    top_k: int = 0
    dispatch: str = "sort"

    def __post_init__(self):
        assert self.dispatch in ("sort", "dense", "ep"), self.dispatch

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba2 SSD hyper-parameters."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    num_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. layer_pattern describes the repeating super-block;
    num_layers must be divisible by its length (scan-over-layers operates on
    super-blocks so heterogeneous stacks stay scannable).

    layer entries: "attn" (+dense ffn), "attn_moe", "mamba", "mamba_moe",
    "local_attn", "global_attn" (gemma2 alternation).
    """

    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    attention: AttentionSpec = AttentionSpec()
    local_attention: Optional[AttentionSpec] = None   # for "local_attn" layers
    # Per-layer window schedule, one entry per layer_pattern position (the
    # SWAA / gemma2 mixed local-global regime). None entries inherit the
    # layer kind's spec unchanged; an int w overrides that position's
    # attention to a causal w-window (sparse specs keep their
    # num_global/softcap, dense specs become plain swat windows). Cache
    # shapes follow: each position allocates its own ring capacity.
    window_schedule: Optional[Tuple[Optional[int], ...]] = None
    moe: MoESpec = MoESpec()
    ssm: SSMSpec = SSMSpec()
    qkv_bias: bool = False                 # qwen2.5
    tie_embeddings: bool = False
    embed_scale: bool = False              # gemma2: x *= sqrt(d_model)
    use_rope: bool = True                  # whisper: sinusoidal instead
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    final_softcap: float = 0.0             # gemma2 final-logit capping
    embed_inputs: bool = True              # False -> frontend stub feeds embeddings
    frontend: str = "none"                 # none | vision | audio (stub type)
    encoder_decoder: bool = False          # whisper
    encoder_layers: int = 0
    max_decode_len: int = 0                # structural decoder limit (whisper: 448)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"pattern {self.layer_pattern}")
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.window_schedule is not None:
            assert len(self.window_schedule) == len(self.layer_pattern), (
                f"{self.name}: window_schedule length "
                f"{len(self.window_schedule)} != layer_pattern length "
                f"{len(self.layer_pattern)}")
            assert all(w is None or w > 0 for w in self.window_schedule), \
                "window_schedule entries must be None or a positive window"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def num_super_blocks(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(k.startswith("mamba") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when prefill cost is o(N^2): SSM/hybrid or windowed attention
        on every attention layer."""
        for i, kind in enumerate(self.layer_pattern):
            if kind.startswith("mamba"):
                continue
            if (self.window_schedule is not None
                    and self.window_schedule[i] is not None):
                continue  # scheduled to a finite window at this position
            spec = (self.local_attention if kind == "local_attn"
                    else self.attention)
            if spec is None or not spec.is_sparse:
                return False
        return True


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assignment: 4 per arch)."""
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"

    def __post_init__(self):
        assert self.mode in ("train", "prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
