"""Expert parallelism with explicit token exchange (shard_map all-to-all).

The pure-SPMD sort/scatter dispatch (moe.py, dispatch="sort") leaves the
token exchange to XLA's partitioner, which lowers the global-index scatters
and gathers into replicate+all-reduce of (E, C_global, D) buffers — measured
at 10.5 TB/device/step on granite-moe x train_4k (EXPERIMENTS.md §Perf cell
1). This module is the schedule every production MoE system actually uses:

  1. each shard routes its LOCAL tokens (router + top-k, replicated weights),
  2. packs them into a (n_shards, cap, D) send buffer by destination shard,
  3. one jax.lax.all_to_all moves tokens to the shards owning their experts,
  4. local sort groups received tokens by local expert, batched FFN,
  5. the reverse all_to_all returns outputs, combined by gate locally.

Wire bytes per device per layer = 2 x t_local*k*cf*D (there and back) — it
scales with LOCAL tokens, independent of the global batch. shard_map runs
partial-manual over the expert axis only, so data-parallel batch dims stay
SPMD-auto.

Capacity: cap = ceil(t_local*k*cf / n_shards) per (src, dst) pair; overflow
drops (standard). cf is per-call so tests can use a no-drop setting.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.types import MoESpec

Params = Dict[str, Any]


def _group_by_dest(ids, cap: int, n_dest: int):
    """Pack routed slots by destination bucket.

    ids: (S,) destination bucket per routed slot. Returns (bucket, rank,
    keep, order): sorted slot order, per-slot rank within its bucket, and
    the keep mask (rank < cap)."""
    order = jnp.argsort(ids)                      # stable
    sorted_ids = ids[order]
    counts = jnp.bincount(sorted_ids, length=n_dest)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(ids.shape[0]) - starts[sorted_ids]
    keep = rank < cap
    return sorted_ids, jnp.where(keep, rank, 0), keep, order


def moe_ffn_ep_local(params: Params, x, spec: MoESpec, *, axis: str,
                     capacity_factor: float = 1.25,
                     return_aux: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Per-shard body (inside shard_map, manual over `axis`).

    x: (B, L_local, D); params['w1'/'w3'/'w2'] carry the LOCAL experts on
    dim 0 (E/n each); params['router'] is replicated (D, E_global)."""
    from repro.core.compat import axis_size
    n = axis_size(axis)
    b, l, d = x.shape
    e = spec.num_experts
    e_local = params["w1"].shape[0]
    assert e_local * n == e, (e_local, n, e)
    k = spec.top_k
    t = b * l
    xf = x.reshape(t, d)

    # ---- 1. local routing ----
    logits = xf.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- 2. pack by destination shard ----
    cap = int(max(1, -(-t * k * capacity_factor // n)))
    flat_ids = expert_ids.reshape(-1)                           # (T*k,)
    dest = flat_ids // e_local
    sorted_dest, rank, keep, order = _group_by_dest(dest, cap, n)
    src_token = order // k
    send = jnp.zeros((n, cap, d), x.dtype).at[sorted_dest, rank].add(
        jnp.where(keep[:, None], xf[src_token], 0).astype(x.dtype))
    # local expert id rides along; -1 marks empty slots
    send_ids = jnp.full((n, cap), -1, jnp.int32).at[sorted_dest, rank].max(
        jnp.where(keep, flat_ids[order] % e_local, -1).astype(jnp.int32))

    # ---- 3. exchange: tokens travel to their experts' shard ----
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=False)                      # (n, cap, D)
    recv_ids = jax.lax.all_to_all(send_ids, axis, split_axis=0,
                                  concat_axis=0, tiled=False)   # (n, cap)

    # ---- 4. local expert FFN (group received tokens by local expert) ----
    rt = n * cap
    rtok = recv.reshape(rt, d)
    rids = recv_ids.reshape(rt)
    cap2 = int(max(1, -(-rt // max(e_local, 1))) * 2)  # 2x slack, local only
    valid = rids >= 0
    # invalid slots go to a virtual bucket e_local: their scatter indices are
    # out of bounds for buf and get dropped (jax scatter OOB semantics), so
    # they can never exhaust a real expert's capacity
    sorted_e, rank2, keep2, order2 = _group_by_dest(
        jnp.where(valid, rids, e_local), cap2, e_local + 1)
    keep2 &= valid[order2]
    buf = jnp.zeros((e_local, cap2, d), x.dtype).at[sorted_e, rank2].add(
        jnp.where(keep2[:, None], rtok[order2], 0).astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w2"])         # (El, C2, D)
    y = jnp.zeros((rt, d), jnp.float32).at[order2].add(
        jnp.where(keep2[:, None], y_buf[sorted_e, rank2], 0)
        .astype(jnp.float32))

    # ---- 5. return trip + gated combine ----
    y_back = jax.lax.all_to_all(y.reshape(n, cap, d).astype(x.dtype), axis,
                                split_axis=0, concat_axis=0, tiled=False)
    gathered = y_back[sorted_dest, rank]                        # (T*k, D)
    w = jnp.where(keep, gate_vals.reshape(-1)[order], 0.0)
    out = jnp.zeros((t, d), jnp.float32).at[src_token].add(
        gathered.astype(jnp.float32) * w[:, None])
    out = out.astype(x.dtype).reshape(b, l, d)

    if return_aux:
        frac = jnp.bincount(flat_ids, length=e).astype(jnp.float32) / (t * k)
        mean_p = jnp.mean(probs, axis=0)
        # frac and mean_p are per-token means: pmean each FACTOR (equal
        # shard sizes), then combine — pmean of the product would differ
        # from the single-pass statistic (product of means != mean of
        # products)
        frac = jax.lax.pmean(frac, axis)
        mean_p = jax.lax.pmean(mean_p, axis)
        return out, e * jnp.sum(frac * mean_p)
    return out, jnp.zeros((), jnp.float32)


def moe_ffn_ep(params: Params, x, spec: MoESpec, *, mesh: Mesh,
               axis: str = "model", capacity_factor: float = 1.25,
               return_aux: bool = True):
    """shard_map wrapper: x (B, L, D) with L sharded over `axis`; expert
    weights sharded on dim 0 over `axis`; router replicated. Partial-manual,
    so batch stays auto (DP on other axes composes)."""
    body = functools.partial(moe_ffn_ep_local, spec=spec, axis=axis,
                             capacity_factor=capacity_factor,
                             return_aux=return_aux)
    in_specs = ({"router": P(), "w1": P(axis), "w3": P(axis),
                 "w2": P(axis)},
                P(None, axis, None))
    from repro.core.compat import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(None, axis, None), P()),
                   axis_names={axis}, check_vma=False)
    return fn({k: params[k] for k in ("router", "w1", "w3", "w2")}, x)


def ep_wire_bytes_per_device(t_local: int, top_k: int, d_model: int,
                             capacity_factor: float = 1.25,
                             bytes_per_el: int = 2) -> int:
    """Analytic all-to-all traffic per layer: there + back, local tokens
    only — independent of global batch (the napkin number §Perf checks)."""
    return int(2 * t_local * top_k * capacity_factor * d_model * bytes_per_el)
