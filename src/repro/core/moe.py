"""Mixture-of-Experts FFN with top-k routing and sort-based dispatch.

Dispatch is the sort/scatter formulation (not the Switch dispatch-einsum,
whose T x E*C x D cost is quadratic in tokens): tokens are argsorted by
expert id, ranked within their expert, dropped beyond capacity, scattered
into an (E, C, D) buffer, run through batched expert FFNs, and combined back
weighted by their gate. Active FLOPs = 3 * 2 * k * T * D * F * cf — matching
the 6*N_active*D roofline convention for MoE.

EP: the expert axis of `w1/w2/w3` and the (E, C, D) buffers shard over
'model' (see distributed/sharding.py); XLA SPMD turns the scatter/gather
into an all-to-all.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.layers import _dense_init
from repro.core.types import MoESpec

Params = Dict[str, Any]


_EP_MESH = None   # set by the launcher (same pattern as ops.set_context_parallel)


def set_expert_parallel(mesh) -> None:
    """Register the mesh the 'ep' dispatch shard_maps over. Explicit module
    state: `jax.sharding.get_abstract_mesh()` does NOT reflect the legacy
    `with mesh:` context, so ambient discovery silently no-ops (learned the
    hard way — §Perf cell 1 it.3a)."""
    global _EP_MESH
    _EP_MESH = mesh


def _ambient_mesh_with(axis: str):
    """The registered (or ambient) mesh when it carries `axis`, else None."""
    if _EP_MESH is not None and axis in _EP_MESH.axis_names:
        return _EP_MESH
    import jax.sharding as jsh
    try:
        mesh = jsh.get_abstract_mesh()
        if mesh is not None and axis in mesh.axis_names:
            return mesh
    except Exception:
        pass
    return None


def _constrain(x, *spec):
    """Best-effort sharding constraint against the ambient mesh (no-op when
    there is no mesh or the axes don't exist — single-device tests)."""
    import jax.sharding as jsh
    try:
        mesh = jsh.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        if any(a is not None and a not in mesh.axis_names
               for part in spec
               for a in ((part,) if isinstance(part, (str, type(None)))
                         else part)):
            return x
        return jax.lax.with_sharding_constraint(x, jsh.PartitionSpec(*spec))
    except Exception:
        return x


def init_moe(key, d_model: int, d_ff: int, spec: MoESpec,
             dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    e = spec.num_experts
    return {
        "router": _dense_init(ks[0], (d_model, e), dtype=jnp.float32),
        "w1": _dense_init(ks[1], (e, d_model, d_ff), dtype=dtype),
        "w3": _dense_init(ks[2], (e, d_model, d_ff), dtype=dtype),
        "w2": _dense_init(ks[3], (e, d_ff, d_model), dtype=dtype),
    }


def moe_ffn(params: Params, x, spec: MoESpec, *,
            capacity_factor: float = 1.25,
            return_aux: bool = True,
            dispatch: str = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, D) -> (B, L, D), aux load-balancing loss (scalar fp32).

    dispatch overrides spec.dispatch per call site. Serving (prefill /
    decode) passes "dense": capacity-based dispatch couples tokens through
    the shared capacity sort, so a row's output would depend on its
    batch-mates and padding — the tokens-stationary combine is exact and
    padding-invariant, which continuous batching requires."""
    b, l, d = x.shape
    e, k = spec.num_experts, spec.top_k
    t = b * l
    xf = x.reshape(t, d)
    dispatch = spec.dispatch if dispatch is None else dispatch

    logits = (xf.astype(jnp.float32) @ params["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)            # renormalize

    if dispatch == "dense":
        out = _dense_combine(params, xf, gate_vals, expert_ids, e)
        out = out.reshape(b, l, d)
        if return_aux:
            flat_e = expert_ids.reshape(-1)
            frac = jnp.bincount(flat_e, length=e).astype(jnp.float32) / (t * k)
            aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
            return out, aux
        return out, jnp.zeros((), jnp.float32)

    if dispatch == "ep":
        mesh = _ambient_mesh_with("model")
        if mesh is not None and e % mesh.shape["model"] == 0:
            from repro.core import moe_ep
            return moe_ep.moe_ffn_ep(params, x, spec, mesh=mesh,
                                     axis="model",
                                     capacity_factor=capacity_factor,
                                     return_aux=return_aux)
        # single-device / indivisible: fall through to the sort schedule

    # ---- sort-based dispatch ----
    cap = int(max(k, min(t, round(t * k * capacity_factor / e))))
    flat_e = expert_ids.reshape(-1)                             # (T*k,)
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    # rank of each routed token within its expert
    counts = jnp.bincount(sorted_e, length=e)                   # (E,)
    starts = jnp.cumsum(counts) - counts                        # (E,)
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < cap
    src_token = order // k                                      # token index
    rank_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, rank_c].add(
        jnp.where(keep[:, None], xf[src_token], 0).astype(x.dtype))
    # EP: keep the dispatch buffers expert-sharded — without the constraint
    # XLA SPMD replicates the (E, C_global, D) buffers on every device
    # (10.5 TB/device collective traffic at train_4k; §Perf cell 1 it.1)
    buf = _constrain(buf, "model", None, None)

    # ---- batched expert FFN ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w2"])         # (E, C, D)
    y_buf = _constrain(y_buf, "model", None, None)

    # ---- combine ----
    gathered = y_buf[sorted_e, rank_c]                          # (T*k, D)
    w = jnp.where(keep, gate_vals.reshape(-1)[order], 0.0)
    out = jnp.zeros((t, d), jnp.float32).at[src_token].add(
        gathered.astype(jnp.float32) * w[:, None])
    out = out.astype(x.dtype).reshape(b, l, d)

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    frac = jnp.bincount(flat_e, length=e).astype(jnp.float32) / (t * k)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return (out, aux) if return_aux else (out, jnp.zeros((), jnp.float32))


def _dense_combine(params: Params, xf, gate_vals, expert_ids, e):
    """Tokens-stationary dispatch: every expert runs on every (local) token,
    outputs combined by the sparse gate matrix. No sort, no scatter, no
    capacity, no all-to-all — the only collective left is the FSDP gather of
    the (small) expert weights. The (E, T_local, F) intermediate stays
    token-sharded under SPMD because xf's token dim is sharded."""
    t = xf.shape[0]
    full_gates = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], expert_ids].set(gate_vals)      # (T, E)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, params["w1"]))
    h = h * jnp.einsum("td,edf->etf", xf, params["w3"])
    y = jnp.einsum("etf,efd->etd", h, params["w2"])             # (E, T, D)
    out = jnp.einsum("te,etd->td", full_gates.astype(y.dtype), y)
    return out.astype(xf.dtype)


def moe_ffn_dense_ref(params: Params, x, spec: MoESpec):
    """O(E) reference: compute every expert for every token, combine by the
    (renormalized) top-k gates. Oracle for tests (no capacity drops)."""
    b, l, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, spec.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    full_gates = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], expert_ids].set(gate_vals)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, params["w1"]))
    h = h * jnp.einsum("td,edf->etf", xf, params["w3"])
    y = jnp.einsum("etf,efd->etd", h, params["w2"])             # (E, T, D)
    out = jnp.einsum("te,etd->td", full_gates, y.astype(jnp.float32))
    return out.astype(x.dtype).reshape(b, l, d)
