"""Composable model assembly: decoder LMs, hybrid (Jamba), MoE, enc-dec.

The stack is organized as `num_super_blocks` repetitions of
`cfg.layer_pattern` (a tuple of layer kinds), scanned with `jax.lax.scan` so
the HLO contains ONE super-block regardless of depth — essential for the
512-fake-device dry-run compile times and the natural seam for pipeline
parallelism / remat.

Layer kinds:
  attn / attn_moe     - GQA attention + (dense | MoE) FFN
  local_attn          - attention with cfg.local_attention spec (gemma2)
  mamba / mamba_moe   - Mamba2 mixer + optional (dense | MoE) FFN
  xattn               - self-attn + cross-attn + FFN (whisper decoder)

Three entry points per model: `loss_fn` (train), `prefill`, `decode_step`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import moe as M
from repro.core import ssm as S
from repro.core.types import AttentionSpec, ModelConfig
from repro.kernels import ops as kops

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def attn_cfg(cfg: ModelConfig, kind: str, cross: bool = False,
             index: Optional[int] = None) -> L.AttentionLayerCfg:
    """index: position within cfg.layer_pattern; when cfg.window_schedule
    names a window there, it overrides this layer's attention spec (sparse
    specs keep num_global/softcap; dense specs become causal swat windows).
    Cache capacities follow the overridden spec, so scheduled layers
    allocate their own ring shapes."""
    spec = cfg.local_attention if kind == "local_attn" else cfg.attention
    if cross:
        spec = AttentionSpec(kind="dense", causal=False)
    elif (index is not None and cfg.window_schedule is not None
          and cfg.window_schedule[index] is not None):
        w = cfg.window_schedule[index]
        if spec.is_sparse:
            spec = dataclasses.replace(spec, window=w)
        else:
            spec = AttentionSpec(kind="swat", window=w, causal=spec.causal,
                                 softcap=spec.softcap)
    return L.AttentionLayerCfg(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        spec=spec, qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope, cross=cross)


# ------------------------------------------------------------------ init ---

def _init_layer(key, cfg: ModelConfig, kind: str,
                index: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_rmsnorm(cfg.d_model)}
    if kind.startswith("mamba"):
        p["mixer"] = S.init_mamba(ks[0], cfg.d_model, cfg.ssm, dtype=dt)
    else:
        p["mixer"] = L.init_attention(ks[0], attn_cfg(cfg, kind, index=index),
                                      dtype=dt)
    if kind == "xattn":
        p["norm_x"] = L.init_rmsnorm(cfg.d_model)
        p["cross"] = L.init_attention(ks[1], attn_cfg(cfg, kind, cross=True),
                                      dtype=dt)
    if kind.endswith("_moe"):
        p["norm2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = M.init_moe(ks[2], cfg.d_model, cfg.d_ff, cfg.moe, dtype=dt)
    elif cfg.d_ff > 0:
        p["norm2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype=dt)
    return p


def _init_super_block(key, cfg: ModelConfig, pattern) -> Params:
    keys = jax.random.split(key, len(pattern))
    return {f"l{i}": _init_layer(keys[i], cfg, kind, index=i)
            for i, kind in enumerate(pattern)}


def init_model(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    k_emb, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    params: Params = {}
    if cfg.embed_inputs:
        params["embed"] = (jax.random.normal(k_emb,
                                             (cfg.vocab_size, cfg.d_model),
                                             jnp.float32) * 0.02).astype(dt)
    blk_keys = jax.random.split(k_blocks, cfg.num_super_blocks)
    params["blocks"] = jax.vmap(
        lambda k: _init_super_block(k, cfg, cfg.layer_pattern))(blk_keys)
    params["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02
        ).astype(dt)
    if cfg.encoder_decoder:
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_super_block(k, cfg_encoder(cfg), ("attn",))
        )(enc_keys)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    return params


@functools.lru_cache(maxsize=64)
def cfg_encoder(cfg: ModelConfig) -> ModelConfig:
    """Whisper encoder: bidirectional self-attention, no causality."""
    return dataclasses.replace(
        cfg, layer_pattern=("attn",), use_rope=False, window_schedule=None,
        attention=dataclasses.replace(cfg.attention, causal=False))


# --------------------------------------------------------------- forward ---

def _apply_layer(p: Params, cfg: ModelConfig, kind: str, x, *,
                 enc_out=None, impl: str, positions=None,
                 index: Optional[int] = None):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind.startswith("mamba"):
        x = x + S.mamba_block(p["mixer"], h, cfg.ssm,
                              chunk=cfg.ssm.chunk_size)
    else:
        x = x + L.attention_layer(p["mixer"], attn_cfg(cfg, kind, index=index),
                                  h, positions=positions, impl=impl)
    if kind == "xattn":
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + L.attention_layer(p["cross"], attn_cfg(cfg, kind, cross=True),
                                  h, kv_x=enc_out, impl=impl)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = M.moe_ffn(p["moe"], h, cfg.moe)
        x = x + y
    elif "mlp" in p:
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h)
    return x, aux


REMAT_POLICIES = {
    # recompute everything in the backward pass: minimum live memory,
    # maximum recompute bytes (the default at 1000-node scale where HBM is
    # the binding constraint)
    "nothing": jax.checkpoint_policies.nothing_saveable,
    # save matmul outputs (attention/FFN dots): ~2x checkpointed activation
    # footprint, removes the recompute of every heavy op from the bwd pass —
    # the memory-roofline lever for small models with HBM headroom
    # (EXPERIMENTS.md §Perf cell 3 it.2)
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _stack_forward(blocks: Params, cfg: ModelConfig, x, pattern, *,
                   enc_out=None, impl: str, remat: bool,
                   act_sharding=None, unroll: bool = False,
                   remat_policy: str = "nothing"):
    def constrain(x):
        return L.with_activation_constraint(x, act_sharding)

    def block_fn(carry, blk_p):
        x, aux = carry
        for i, kind in enumerate(pattern):
            x, a = _apply_layer(blk_p[f"l{i}"], cfg, kind, x,
                                enc_out=enc_out, impl=impl, index=i)
            aux = aux + a
        return (constrain(x), aux), None

    if remat:
        block_fn = jax.checkpoint(block_fn,
                                  policy=REMAT_POLICIES[remat_policy])
    n_blocks = jax.tree.leaves(blocks)[0].shape[0]
    (x, aux), _ = jax.lax.scan(block_fn,
                               (constrain(x), jnp.zeros((), jnp.float32)),
                               blocks,
                               unroll=n_blocks if unroll else 1)
    return x, aux


def embed_tokens(params: Params, cfg: ModelConfig, batch: Dict[str, Any]):
    if "embeddings" in batch:
        # modality-frontend stub (VLM patches / audio frames): precomputed
        # embeddings bypass the token table
        x = batch["embeddings"].astype(_dtype(cfg))
    elif cfg.embed_inputs:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        raise ValueError("batch needs 'tokens' or 'embeddings'")
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if not cfg.use_rope:  # sinusoidal absolute positions (whisper)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model
                                       ).astype(x.dtype)[None]
    return x


def _unembed(params: Params, cfg: ModelConfig, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jax.lax.dot_general(
        x, head, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return L.softcap(logits, cfg.final_softcap)


def encode(params: Params, cfg: ModelConfig, batch):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = batch["enc_embeddings"].astype(_dtype(cfg))
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model
                                   ).astype(x.dtype)[None]
    ecfg = cfg_encoder(cfg)
    x, _ = _stack_forward(params["enc_blocks"], ecfg, x, ("attn",),
                          impl="xla", remat=False)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward_logits(params: Params, cfg: ModelConfig, batch, *,
                   impl: str = "xla", remat: bool = True,
                   act_sharding=None, unroll: bool = False,
                   remat_policy: str = "nothing"):
    enc_out = encode(params, cfg, batch) if cfg.encoder_decoder else None
    x = embed_tokens(params, cfg, batch)
    x, aux = _stack_forward(params["blocks"], cfg, x, cfg.layer_pattern,
                            enc_out=enc_out, impl=impl, remat=remat,
                            act_sharding=act_sharding, unroll=unroll,
                            remat_policy=remat_policy)
    return _unembed(params, cfg, x), aux


def loss_fn(params: Params, cfg: ModelConfig, batch, *,
            impl: str = "xla", remat: bool = True,
            aux_weight: float = 0.01, act_sharding=None,
            unroll: bool = False, remat_policy: str = "nothing"):
    """Next-token cross entropy. batch["labels"]: (B, L) int32; positions
    with label < 0 are masked out."""
    logits, aux = forward_logits(params, cfg, batch, impl=impl, remat=remat,
                                 act_sharding=act_sharding, unroll=unroll,
                                 remat_policy=remat_policy)
    labels = batch["labels"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = labels[:, 1:]
    valid = targets >= 0
    tsafe = jnp.where(valid, targets, 0)
    # TP-safe cross entropy: no take_along_axis over the (model-sharded)
    # vocab axis — a dynamic gather there makes SPMD all-gather the full
    # logits (~67 GB/step for a 256k vocab; EXPERIMENTS.md §Perf it.2).
    # iota==label masking keeps every op vocab-partitioned; the only
    # collectives are the (B, L)-sized max/sum partial reductions.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    hit = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
           == tsafe[..., None])
    picked = jnp.sum(jnp.where(hit, z, 0.0), axis=-1)
    nll = jnp.where(valid, lse - picked, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": denom.astype(jnp.float32)}


# --------------------------------------------------------------- serving ---

def _layer_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      enc_len: int = 0, lookahead: int = 0,
                      index: Optional[int] = None):
    dt = _dtype(cfg)
    if kind.startswith("mamba"):
        return S.init_mamba_cache(cfg.d_model, cfg.ssm, batch, dtype=dt)
    cache = L.init_kv_cache(attn_cfg(cfg, kind, index=index), batch, max_len,
                            dtype=dt, lookahead=lookahead)
    if kind == "xattn":
        shape = (batch, cfg.num_kv_heads, max(enc_len, 1),
                 cfg.resolved_head_dim)
        cache["xk"] = jnp.zeros(shape, dt)
        cache["xv"] = jnp.zeros(shape, dt)
    return cache


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                enc_len: int = 0, lookahead: int = 0) -> Params:
    """Stacked (num_super_blocks leading dim) decode caches. lookahead:
    extra ring rows per layer so a (lookahead+1)-token decode step never
    evicts an in-window token (`layers.cache_capacity`)."""
    def one(_):
        return {f"l{i}": _layer_cache_init(cfg, kind, batch, max_len,
                                           enc_len, lookahead, index=i)
                for i, kind in enumerate(cfg.layer_pattern)}
    caches = jax.vmap(one)(jnp.arange(cfg.num_super_blocks))
    return caches


def paged_layout(cfg: ModelConfig, max_len: int, lookahead: int = 0,
                 page: int = 0) -> Dict[int, Dict[str, int]]:
    """Block geometry per attention-bearing layer_pattern position: the
    host-side contract between `init_paged_caches` and the serving block
    allocator. Keys are pattern indices; values carry the page size, blocks
    per slot (nb), logical capacity, pinned-global count g and ring modulus
    — everything the allocator needs to map token positions to blocks."""
    page = page or L.PAGE_SIZE
    out: Dict[int, Dict[str, int]] = {}
    for i, kind in enumerate(cfg.layer_pattern):
        if kind.startswith("mamba"):
            continue
        acfg = attn_cfg(cfg, kind, index=i)
        cap = L.cache_capacity(acfg, max_len, lookahead)
        g = acfg.spec.num_global if acfg.spec.is_sparse else 0
        out[i] = {"page": page,
                  "nb": L.paged_num_blocks(acfg, max_len, lookahead, page),
                  "cap": cap, "g": g, "ring": cap - g}
    return out


def _layer_cache_init_paged(cfg: ModelConfig, kind: str, batch: int,
                            max_len: int, enc_len: int = 0,
                            lookahead: int = 0, index: Optional[int] = None,
                            shared_pool: bool = True):
    dt = _dtype(cfg)
    if kind.startswith("mamba"):
        return S.init_mamba_cache(cfg.d_model, cfg.ssm, batch, dtype=dt)
    cache = L.init_paged_kv_cache(attn_cfg(cfg, kind, index=index), batch,
                                  max_len, dtype=dt, lookahead=lookahead,
                                  shared_pool=shared_pool)
    if kind == "xattn":
        shape = (batch, cfg.num_kv_heads, max(enc_len, 1),
                 cfg.resolved_head_dim)
        cache["xk"] = jnp.zeros(shape, dt)
        cache["xv"] = jnp.zeros(shape, dt)
    return cache


def init_paged_caches(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 0, lookahead: int = 0,
                      shared_pool: bool = True) -> Params:
    """Paged twin of `init_caches`: attention layers hold block pools +
    tables (`layers.init_paged_kv_cache`), mamba/xattn leaves are unchanged.
    Identity tables make a fresh paged cache gather-equal to a fresh
    contiguous one."""
    def one(_):
        return {f"l{i}": _layer_cache_init_paged(
                    cfg, kind, batch, max_len, enc_len, lookahead,
                    index=i, shared_pool=shared_pool)
                for i, kind in enumerate(cfg.layer_pattern)}
    return jax.vmap(one)(jnp.arange(cfg.num_super_blocks))


def _apply_layer_decode(p, cfg, kind, x, cache, *, enc_out=None,
                        impl: str = "ref", lookahead: int = 0,
                        index: Optional[int] = None):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind.startswith("mamba"):
        y, new_cache = S.mamba_decode(p["mixer"], h, cache, cfg.ssm)
    else:
        y, new_cache = L.attention_decode(p["mixer"],
                                          attn_cfg(cfg, kind, index=index),
                                          h, cache, impl=impl,
                                          lookahead=lookahead)
    x = x + y
    if kind == "xattn":
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        ccfg = attn_cfg(cfg, kind, cross=True)
        q, _, _ = L._project_qkv(p["cross"], ccfg, h, h)
        out = kops.decode_attention(
            q, cache["xk"], cache["xv"],
            jnp.full((x.shape[0], 1, 1, 1), cache["xk"].shape[2], jnp.int32),
            ccfg.spec)
        out = out.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], -1)
        x = x + out @ p["cross"]["wo"]
        new_cache = {**new_cache, "xk": cache["xk"], "xv": cache["xv"]}
    if "moe" in p:
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = M.moe_ffn(p["moe"], h, cfg.moe, dispatch="dense")
        x = x + y
    elif "mlp" in p:
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h)
    return x, new_cache


def decode_step(params: Params, cfg: ModelConfig, batch, caches, *,
                impl: str = "xla", unroll: bool = False,
                act_sharding=None, lookahead: int = 0):
    """T tokens for every sequence (usually T=1). batch:
    {"tokens": (B, T)} (or {"embeddings": (B, T, D)}). Per-slot cache
    steps: rows may sit at different positions (continuous batching).
    T > 1 is the speculative-decode verify primitive: the whole stack runs
    once for T draft tokens, each query masked to its own causal/window
    slice of the ring (attention-pattern configs only — mamba state updates
    are sequential), and needs caches allocated with lookahead >= T-1.
    impl="pallas" routes the cache attention through the fused swat_decode
    kernel (ring insert + attention in one pass); anything else uses the
    jnp reference. act_sharding: optional (B, T, D) sharding pinned at
    every super-block boundary — under a serving mesh this keeps the decode
    residual stream slot-sharded instead of letting SPMD replicate it
    between blocks. Returns (logits (B, T, V), new caches)."""
    x = embed_tokens(params, cfg, batch)
    assert x.shape[1] == 1 or all(
        not k.startswith("mamba") for k in cfg.layer_pattern), \
        "multi-token decode: mamba layers update state one token at a time"
    dec_impl = "pallas" if impl == "pallas" else "ref"

    def block_fn(x, inp):
        blk_p, blk_cache = inp
        new_caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc = _apply_layer_decode(blk_p[f"l{i}"], cfg, kind, x,
                                        blk_cache[f"l{i}"], impl=dec_impl,
                                        lookahead=lookahead, index=i)
            new_caches[f"l{i}"] = nc
        return L.with_activation_constraint(x, act_sharding), new_caches

    x, new_caches = jax.lax.scan(
        block_fn, x, (params["blocks"], caches),
        unroll=cfg.num_super_blocks if unroll else 1)
    return _unembed(params, cfg, x), new_caches


def prefill(params: Params, cfg: ModelConfig, batch, max_len: int, *,
            impl: str = "xla", unroll: bool = False, lengths=None,
            act_sharding=None, lookahead: int = 0):
    """Run the prompt, return (last-position logits, primed caches).

    Implemented as forward + cache extraction per layer: each attention layer
    re-projects K/V into its (ring) cache; mamba layers replay their final
    state. Prompt length L <= max_len.

    lengths: optional (B,) int32 real prompt lengths for a right-padded
    batched prefill — per-row cache steps, SSM states stopped at each row's
    length, and logits gathered at each row's last real token. Causality
    makes the pad tail inert for every valid position.

    act_sharding: optional (B, L, D) sharding pinned at super-block
    boundaries (serving-mesh prefill keeps rows batch-sharded)."""
    if lengths is not None:
        assert not cfg.encoder_decoder, "padded prefill: decoder-only"
    enc_out = encode(params, cfg, batch) if cfg.encoder_decoder else None
    x = embed_tokens(params, cfg, batch)
    l = x.shape[1]

    def block_fn(carry, blk_p):
        x, = carry
        new_caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            p = blk_p[f"l{i}"]
            h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
            if kind.startswith("mamba"):
                y = S.mamba_block(p["mixer"], h, cfg.ssm,
                                  chunk=cfg.ssm.chunk_size)
                cache = _mamba_prefill_cache(p["mixer"], h, cfg,
                                             lengths=lengths)
            else:
                acfg = attn_cfg(cfg, kind, index=i)
                y = L.attention_layer(p["mixer"], acfg, h, impl=impl)
                cache = L.prefill_kv_cache(p["mixer"], acfg, h, max_len,
                                           lengths=lengths,
                                           lookahead=lookahead)
            x = x + y
            if kind == "xattn":
                h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
                ccfg = attn_cfg(cfg, kind, cross=True)
                x = x + L.attention_layer(p["cross"], ccfg, h, kv_x=enc_out,
                                          impl=impl)
                _, xk, xv = L._project_qkv(p["cross"], ccfg, enc_out, enc_out)
                cache = {**cache, "xk": xk, "xv": xv}
            if "moe" in p:
                h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
                y, _ = M.moe_ffn(p["moe"], h, cfg.moe, dispatch="dense")
                x = x + y
            elif "mlp" in p:
                h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
                x = x + L.mlp(p["mlp"], h)
            new_caches[f"l{i}"] = cache
        return (L.with_activation_constraint(x, act_sharding),), new_caches

    (x,), caches = jax.lax.scan(
        block_fn, (x,), params["blocks"],
        unroll=cfg.num_super_blocks if unroll else 1)
    if lengths is None:
        last = x[:, -1:]
    else:
        idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, l - 1)
        last = jnp.take_along_axis(
            x, jnp.broadcast_to(idx[:, None, None],
                                (x.shape[0], 1, x.shape[2])), axis=1)
    logits = _unembed(params, cfg, last)
    return logits, caches


def prefill_chunkable(cfg: ModelConfig) -> bool:
    """Whether `prefill_chunk` supports this config: rope attention-only
    patterns (mamba carries state between chunks we don't thread; xattn /
    sinusoidal-position configs take the single-shot path). The single
    source of truth for the engine's chunking decision."""
    return cfg.use_rope and all(
        not k.startswith("mamba") and k != "xattn"
        for k in cfg.layer_pattern)


def speculative_supported(cfg: ModelConfig) -> bool:
    """Whether the serving engine may run speculative decode on this
    config: every layer's decode state must be a ring KV cache whose
    `step` pointer can be rolled back after a rejected draft (mamba's
    recurrent state and xattn's encoder memory have no such rollback), and
    positions must be rotary so a (B, T) verify step is position-exact.
    The single source of truth for the engine's `speculative=` gate."""
    return prefill_chunkable(cfg)


def prefill_chunk(params: Params, cfg: ModelConfig, batch, caches, pos0,
                  lengths, act_sharding=None, lookahead: int = 0):
    """One lockstep chunk of a batched chunked prefill: run tokens
    [pos0, pos0+T) through the stack against the ring caches and append to
    them. Exact-band equal to single-shot `prefill`, but per-layer score
    memory is O(T * (cap + T)) — prefill VMEM is bounded by the chunk size,
    not the prompt length. Attention-only rope patterns (mamba/xattn configs
    take the single-shot path). pos0 may be traced: one compiled chunk
    function serves every chunk index. Returns (hidden states (B, T, D),
    new caches) — unembedding is the caller's job, which gathers the one
    last-real-token row per sequence first (a full-vocab projection of
    every prompt token would dwarf the chunking savings)."""
    assert prefill_chunkable(cfg), cfg.layer_pattern
    x = embed_tokens(params, cfg, batch)

    def block_fn(x, inp):
        blk_p, blk_cache = inp
        new_caches = {}
        for i, kind in enumerate(cfg.layer_pattern):
            p = blk_p[f"l{i}"]
            h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
            y, nc = L.attention_prefill_chunk(
                p["mixer"], attn_cfg(cfg, kind, index=i), h,
                blk_cache[f"l{i}"], pos0, lengths, lookahead=lookahead)
            x = x + y
            if "moe" in p:
                h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
                y, _ = M.moe_ffn(p["moe"], h, cfg.moe, dispatch="dense")
                x = x + y
            elif "mlp" in p:
                h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
                x = x + L.mlp(p["mlp"], h)
            new_caches[f"l{i}"] = nc
        return L.with_activation_constraint(x, act_sharding), new_caches

    x, new_caches = jax.lax.scan(block_fn, x, (params["blocks"], caches))
    return x, new_caches


def _mamba_prefill_cache(p, h, cfg: ModelConfig, lengths=None):
    """Final SSM + conv state after a full-sequence mamba pass. With
    `lengths`, each row's state is stopped at its own last real token: a
    zeroed dt beyond the length makes decay exp(0)=1 and update 0, so the
    cumulative scan freezes, and the conv window is gathered per row."""
    spec = cfg.ssm
    bsz, l, dm = h.shape
    di = spec.d_inner(dm)
    g, sdim = spec.num_groups, spec.state_dim
    zxbcdt = h @ p["in_proj"]
    _, xin, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * sdim],
                               -1)
    conv_in = jnp.concatenate([xin, bc], -1)
    kw = spec.conv_width
    if lengths is None:
        conv_state = conv_in[:, -(kw - 1):, :]
        if l < kw - 1:
            conv_state = jnp.pad(conv_in, ((0, 0), (kw - 1 - l, 0), (0, 0)))
    else:
        # per-row window [len-kw+1, len); zero-fill where it precedes t=0
        lens = jnp.asarray(lengths, jnp.int32)
        idx = lens[:, None] - (kw - 1) + jnp.arange(kw - 1)[None, :]
        gathered = jnp.take_along_axis(
            conv_in, jnp.broadcast_to(jnp.maximum(idx, 0)[:, :, None],
                                      (bsz, kw - 1, conv_in.shape[-1])),
            axis=1)
        conv_state = jnp.where((idx >= 0)[:, :, None], gathered, 0.0)
    conv_out = jax.nn.silu(S._causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xin2, b_mat, c_mat = jnp.split(conv_out, [di, di + g * sdim], -1)
    nh = spec.num_heads(dm)
    xh = xin2.reshape(bsz, l, nh, spec.head_dim)
    b_mat = b_mat.reshape(bsz, l, g, sdim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        # freeze the recurrence past each row's length: dt=0 -> decay 1,
        # update 0, so state(L) == state(len)
        tmask = (jnp.arange(l)[None, :]
                 < jnp.asarray(lengths, jnp.int32)[:, None])
        dtv = dtv * tmask[..., None]
    a = -jnp.exp(p["a_log"])
    # state = sum_j exp(sum_{k>j} dt_k a) dt_j B_j x_j  — one pass, fp32
    da = dtv * a
    cum = jnp.cumsum(da, axis=1)
    w = jnp.exp(cum[:, -1:, :] - cum)                      # (B,L,H)
    bm = jnp.repeat(b_mat, nh // g, axis=2)
    ssm = jnp.einsum("blh,blhs,blhp->bhps",
                     w * dtv, bm.astype(jnp.float32),
                     xh.astype(jnp.float32))
    return {"conv": conv_state.astype(_dtype(cfg)), "ssm": ssm}
