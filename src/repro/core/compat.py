"""jax version-compatibility shims.

The code targets the modern `jax.shard_map` API (axis_names / check_vma);
the pinned container ships jax 0.4.x where shard_map lives in
jax.experimental with the (auto / check_rep) spelling. One wrapper keeps
every call site on the modern vocabulary.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Modern-signature shard_map that lowers to whichever API exists.

    axis_names: the MANUAL axes (partial-manual mode); None = all mesh axes.
    check_vma maps to legacy check_rep."""
    names = set(mesh.axis_names if axis_names is None else axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(a for a in mesh.axis_names if a not in names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def axis_size(name) -> int:
    """Static size of a (manual) mesh axis from inside shard_map —
    `jax.lax.axis_size` on modern jax, the axis env on 0.4.x."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    import jax.core as jcore
    return jcore.axis_frame(name)
