"""Deterministic synthetic LM data pipeline.

Real-cluster posture without real data: a seeded, shard-aware token stream
whose content is a *learnable* synthetic language (Zipf unigrams + copy
spans + induction patterns), so training loss decreases meaningfully in the
examples and window-vs-dense comparisons are non-trivial.

Determinism contract: batch(step, shard) depends only on (seed, step,
shard) — restart-safe (checkpoint stores the step; resume regenerates the
identical stream) and elastic-safe (re-sharding re-partitions the same
global batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1
    copy_span: int = 32         # induction-head fodder: repeated spans
    pad_id: int = -1


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self._probs = probs / probs.sum()

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            np.uint32((cfg.seed * 1_000_003 + step) % (2**31 - 1)))
        b, l = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, l),
                          p=self._probs).astype(np.int32)
        # copy structure: second half of each span repeats the first half
        span = cfg.copy_span
        for s in range(0, l - 2 * span + 1, 4 * span):
            toks[:, s + span:s + 2 * span] = toks[:, s:s + span]
        return {"tokens": toks, "labels": toks.copy()}

    def shard_batch(self, step: int, shard: int, num_shards: int
                    ) -> Dict[str, np.ndarray]:
        g = self.global_batch(step)
        b = self.cfg.global_batch
        assert b % num_shards == 0, (b, num_shards)
        per = b // num_shards
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in g.items()}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.global_batch(step)
            step += 1


def make_host_loader(cfg: DataConfig, mesh=None):
    """Returns batch_fn(step) -> numpy global batch, placed by the caller
    (jax.device_put with the batch sharding)."""
    ds = SyntheticLM(cfg)
    return ds.global_batch
