"""swatlint: static analysis of the jitted serving surface.

Traces every `_Compiled` entry point on ShapeDtypeStructs (no real
compute) and proves the hot-path invariants hold: carries donated and
aliased, no host callbacks in scan bodies, slot-parallel decode
collective-free, TP within blessed wire-byte budgets, no bf16->f32
matmul upcasts, and a pinned lowering count per entry family.

CLI: `python -m repro.launch.analyze` (--check / --write). Committed
baseline: ANALYSIS.json at the repo root.
"""
from repro.analysis.rules import (ERROR, WARN, Finding,  # noqa: F401
                                  audit_recompiles, check_donation,
                                  check_dtype_promotion, check_host_sync,
                                  check_collectives, lowering_counts)
from repro.analysis.tracer import (EntryPoint, LeafInfo,  # noqa: F401
                                   TracedEntry, compiled_alias_pairs,
                                   donated_arg_indices, engine_entry_points,
                                   trace, walk_jaxpr)
from repro.analysis.report import (analyze_engine,  # noqa: F401
                                   analyze_entry_points, merge_reports)
from repro.analysis import baselines  # noqa: F401
