"""swatlint rule families over traced entry points.

Six families, each a pure function `TracedEntry -> [Finding]` (plus one
matrix-level audit over the whole traced set):

  donation          every declared carry leaf donated in StableHLO AND
                    aliased in the compiled executable; generic catch for
                    large aliasable inputs nobody declared
  host_sync         no pure/io/debug callbacks or device_put inside loop
                    bodies; callbacks anywhere on the decode hot path
  collectives       per-entry `CollectiveBudget` over the partitioned HLO —
                    slot-parallel decode must be collective-free
  dtype_promotion   bf16 values upcast to f32 then fed to matmuls
  recompile         distinct compile keys per entry family across the
                    serving matrix + weak-type leaks into compile keys
  telemetry         metrics-carrying scans ("metrics" tag): the counter
                    carry must be donated+aliased like the caches, and the
                    instrumented program stays held to the same zero-
                    callback / zero-collective budgets — proof that
                    observability adds no host syncs to the hot path

Severity contract: "error" findings fail `analyze --check` outright;
"warn" findings fail only when their count grows past the committed
baseline (see baselines.diff).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence

from repro.distributed.hlo_analysis import (CollectiveBudget, check_budget,
                                            parse_collectives)
from repro.analysis.tracer import TracedEntry, sub_jaxprs, walk_jaxpr

ERROR = "error"
WARN = "warn"

# Leaves at/above this size trip the generic "large aliasable input is not
# donated" rule even when no carry was declared. Smoke-scale ring caches are
# ~256 KiB/leaf (multi-MB at production scale), so 128 KiB keeps the rule
# live in CI instead of only at scale.
DEFAULT_MIN_CARRY_BYTES = 128 * 1024

_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                   "callback"}
_TRANSFER_PRIMS = {"device_put"}
_LOOP_PRIMS = {"scan", "while"}
_MATMUL_PRIMS = {"dot_general", "conv_general_dilated"}


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str
    entry: str
    message: str
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------- donation --

def check_donation(tr: TracedEntry, *,
                   min_bytes: int = DEFAULT_MIN_CARRY_BYTES
                   ) -> List[Finding]:
    out: List[Finding] = []
    name = tr.point.name
    aliased_inputs = {i for i, _ in tr.alias_pairs}

    for argnum in tr.point.carries:
        leaves = tr.arg_leaves(argnum)
        missing = [l for l in leaves if l.index not in tr.donated]
        nbytes = sum(l.nbytes for l in leaves)
        if missing:
            out.append(Finding(
                "donation", ERROR, name,
                f"carry arg {argnum} is not donated: {len(missing)}/"
                f"{len(leaves)} leaves copied every call (~{nbytes} B)",
                {"argnum": argnum, "carry_bytes": nbytes,
                 "undonated_leaves": len(missing)}))
        elif tr.compiled_hlo is not None:
            dead = [l for l in leaves if l.index in tr.donated
                    and l.index not in aliased_inputs
                    and l.index not in tr.pruned]
            if dead:
                out.append(Finding(
                    "donation", ERROR, name,
                    f"carry arg {argnum} is donated but {len(dead)} leaves "
                    "have no input-output alias in the compiled executable "
                    "— XLA dropped the donation (silent copy)",
                    {"argnum": argnum,
                     "unaliased_leaves": [l.index for l in dead]}))

    # Generic catch: a large input whose exact (shape, dtype) also appears
    # in the outputs and is neither donated nor a declared carry is almost
    # certainly a forgotten carry. Warn-level: params legitimately flow
    # through some training entry points.
    declared = set(tr.point.carries)
    out_sigs: Dict[tuple, int] = defaultdict(int)
    for l in tr.out_leaves:
        out_sigs[(l.shape, l.dtype)] += 1
    matched: Dict[tuple, int] = defaultdict(int)
    for l in tr.in_leaves:
        if l.argnum in declared or l.index in tr.donated:
            matched[(l.shape, l.dtype)] += 1
    for l in tr.in_leaves:
        if l.argnum in declared or l.index in tr.donated:
            continue
        if l.nbytes < min_bytes:
            continue
        sig = (l.shape, l.dtype)
        if matched[sig] < out_sigs.get(sig, 0):
            matched[sig] += 1
            out.append(Finding(
                "donation", WARN, name,
                f"input leaf {l.index} (arg {l.argnum}, {l.dtype}"
                f"{list(l.shape)}, {l.nbytes} B) matches an output shape "
                "but is not donated — likely a forgotten carry",
                {"leaf": l.index, "argnum": l.argnum, "bytes": l.nbytes}))
    return out


# --------------------------------------------------------------- host sync --

def check_host_sync(tr: TracedEntry) -> List[Finding]:
    out: List[Finding] = []
    name = tr.point.name
    hot = "decode_hot_path" in tr.point.tags

    def visit(eqn, ctx):
        prim = eqn.primitive.name
        in_loop = any(c in _LOOP_PRIMS for c in ctx)
        if prim in _CALLBACK_PRIMS:
            sev = ERROR if (in_loop or hot) else WARN
            where = f"inside {'/'.join(ctx)}" if ctx else "at top level"
            out.append(Finding(
                "host_sync", sev, name,
                f"host callback `{prim}` {where} — every execution "
                "synchronizes with Python",
                {"primitive": prim, "context": list(ctx)}))
        elif prim in _TRANSFER_PRIMS and in_loop:
            # device_put with no target device and ALIAS copy semantics is
            # a no-op placement annotation, not a transfer — skip those
            devices = eqn.params.get("devices", ())
            if any(d is not None for d in devices):
                out.append(Finding(
                    "host_sync", WARN, name,
                    f"`{prim}` inside {'/'.join(ctx)} — transfer in a "
                    "loop body",
                    {"primitive": prim, "context": list(ctx)}))

    walk_jaxpr(tr.jaxpr, visit)
    return out


# -------------------------------------------------------------- collectives --

def budget_for(tr: TracedEntry,
               baseline_budgets: Optional[Dict[str, dict]] = None
               ) -> Optional[CollectiveBudget]:
    """Resolve the collective budget for one entry point.

    Single-host entries are collective-free by construction and required
    to stay so. Slot-parallel DECODE entries (the hot path) must also be
    collective-free — decoding is embarrassingly parallel over slots, so
    any all-gather/all-reduce there is a sharding leak. Slot-parallel
    block-boundary entries (admission insert, ragged n=1 prefill) may
    legitimately reshard, and TP entries legitimately reduce activations:
    those check against the blessed baseline budget when one exists; with
    no baseline (bless mode) this returns None and the caller records the
    measured profile as the new budget.
    """
    tags = tr.point.tags
    if "single" in tags or ("decode_hot_path" in tags and "tp" not in tags):
        return CollectiveBudget.collective_free()
    if baseline_budgets:
        b = baseline_budgets.get(tr.point.family)
        if b is not None:
            return CollectiveBudget(
                allow=tuple(sorted(b.get("allow", {}).items())),
                max_wire_bytes=float(b.get("max_wire_bytes", 0.0)))
    return None


def check_collectives(tr: TracedEntry,
                      budget: Optional[CollectiveBudget]) -> List[Finding]:
    if tr.compiled_hlo is None or budget is None:
        return []
    stats = parse_collectives(tr.compiled_hlo)
    return [
        Finding("collectives", ERROR, tr.point.name, v,
                {"counts": stats.counts, "wire_bytes": stats.wire_bytes})
        for v in check_budget(stats, budget)
    ]


# --------------------------------------------------------- dtype promotion --

def check_dtype_promotion(tr: TracedEntry) -> List[Finding]:
    """Flag bf16 -> f32 upcasts that feed matmuls.

    Taint is tracked per (sub-)jaxpr: a `convert_element_type` from bf16 to
    f32 taints its output var; a dot/conv consuming a tainted var means the
    contraction runs at f32 width in what the author declared a bf16 path —
    2x the HBM traffic and usually an accident. Intentional f32 accumulation
    via `preferred_element_type` does NOT trip this (no convert involved).
    """
    out: List[Finding] = []
    name = tr.point.name

    def scan(jaxpr_like):
        jaxpr = getattr(jaxpr_like, "jaxpr", jaxpr_like)
        tainted = set()
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "convert_element_type":
                try:
                    src = str(eqn.invars[0].aval.dtype)
                    dst = str(eqn.outvars[0].aval.dtype)
                except Exception:
                    src = dst = ""
                if src == "bfloat16" and dst == "float32":
                    tainted.add(eqn.outvars[0])
            elif prim in _MATMUL_PRIMS:
                if any(v in tainted for v in eqn.invars
                       if hasattr(v, "aval") and not _is_literal(v)):
                    out.append(Finding(
                        "dtype_promotion", WARN, name,
                        f"`{prim}` consumes a bf16->f32 upcast operand — "
                        "contraction runs at f32 width in a bf16 path",
                        {"primitive": prim}))
            for sub in sub_jaxprs(eqn.params):
                scan(sub)

    scan(tr.jaxpr)
    return out


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"


# --------------------------------------------------------------- telemetry --

def check_telemetry(tr: TracedEntry) -> List[Finding]:
    """Prove the metrics carry is free: entries tagged "metrics" (scans
    compiled with device counters) must carry the counter pytree as their
    LAST argument, all-int32, donated AND aliased in the executable — an
    in-place accumulator, not a per-block copy. The same entries still run
    under the host_sync / collectives budgets (those families fire on the
    instrumented jaxpr/HLO directly), so together the three families prove
    telemetry adds zero host syncs, zero collectives, and zero copies."""
    if "metrics" not in tr.point.tags:
        return []
    out: List[Finding] = []
    name = tr.point.name
    mx_argnum = len(tr.point.args) - 1   # by construction: mx rides last
    leaves = tr.arg_leaves(mx_argnum)
    if not leaves:
        return [Finding(
            "telemetry", ERROR, name,
            "entry tagged `metrics` but its last argument has no leaves — "
            "the counter carry is missing from the traced signature",
            {"argnum": mx_argnum})]
    wrong = [l for l in leaves if l.dtype != "int32"]
    if wrong:
        out.append(Finding(
            "telemetry", ERROR, name,
            f"{len(wrong)} counter leaves are not int32 — a dtype "
            "promotion snuck into the metrics carry",
            {"leaves": [(l.index, l.dtype) for l in wrong]}))
    undonated = [l for l in leaves if l.index not in tr.donated]
    if undonated:
        out.append(Finding(
            "telemetry", ERROR, name,
            f"metrics carry (arg {mx_argnum}) is not donated: "
            f"{len(undonated)}/{len(leaves)} counter leaves copied every "
            "block instead of accumulating in place",
            {"argnum": mx_argnum,
             "undonated_leaves": [l.index for l in undonated]}))
    elif tr.compiled_hlo is not None:
        aliased = {i for i, _ in tr.alias_pairs}
        dead = [l for l in leaves if l.index not in aliased
                and l.index not in tr.pruned]
        if dead:
            out.append(Finding(
                "telemetry", ERROR, name,
                f"metrics carry donated but {len(dead)} counter leaves "
                "have no input-output alias in the executable — XLA "
                "dropped the donation (silent copy per block)",
                {"unaliased_leaves": [l.index for l in dead]}))
    return out


# --------------------------------------------------------- recompile audit --

def audit_recompiles(traced: Sequence[TracedEntry], *,
                     max_per_family: Optional[Dict[str, int]] = None
                     ) -> List[Finding]:
    """Matrix-level audit: weak-type leaks + lowering counts per family.

    Distinct compile keys per family are expected (the batch/steps matrix is
    deliberate); the committed baseline pins the count and `analyze --check`
    fails when it grows. Weak types in entry-point signatures are flagged
    here directly: a weak-typed scalar gives Python-int and jnp.int32 calls
    DIFFERENT compile keys for identical compute.
    """
    out: List[Finding] = []
    by_family: Dict[str, set] = defaultdict(set)
    for tr in traced:
        by_family[tr.point.family].add(tr.compile_key)
        weak = [l for l in tr.in_leaves if l.weak_type]
        if weak:
            out.append(Finding(
                "recompile", WARN, tr.point.name,
                f"{len(weak)} weak-typed leaves in the traced signature — "
                "weak types fork compile keys for identical compute",
                {"leaves": [l.index for l in weak]}))
    for family, keys in sorted(by_family.items()):
        cap = (max_per_family or {}).get(family)
        if cap is not None and len(keys) > cap:
            out.append(Finding(
                "recompile", ERROR, family,
                f"family `{family}` has {len(keys)} distinct lowerings "
                f"(baseline allows {cap}) — a shape or dtype leak is "
                "forking the compile cache",
                {"lowerings": len(keys), "baseline": cap}))
    return out


def lowering_counts(traced: Sequence[TracedEntry]) -> Dict[str, int]:
    by_family: Dict[str, set] = defaultdict(set)
    for tr in traced:
        by_family[tr.point.family].add(tr.compile_key)
    return {f: len(k) for f, k in sorted(by_family.items())}
