"""ANALYSIS.json baseline: load/save/diff + the artifact gate.

The committed baseline is the same contract as BENCH_*.json: a fresh run on
a clean tree must reproduce it within its own headroom. `--check` fails on

  * any error-severity finding in the fresh report,
  * per-rule warning counts growing past the baseline,
  * per-family lowering counts growing past the baseline (a shape or
    weak-type leak forking the compile cache),
  * engines present in the baseline but missing from the fresh run.

New engines/families in the fresh run are reported but do NOT fail — they
fail the separate "baseline is stale" check so the author is told to bless
(`analyze --write`) in the same PR that adds the entry point.
"""
from __future__ import annotations

import json
import os
from collections import Counter
from typing import Dict, List, Optional

DEFAULT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "ANALYSIS.json")


def resolve_path(path: Optional[str] = None) -> str:
    return os.path.abspath(path or DEFAULT_PATH)


def load(path: Optional[str] = None) -> Optional[dict]:
    p = resolve_path(path)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def save(report: dict, path: Optional[str] = None) -> str:
    p = resolve_path(path)
    with open(p, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return p


def _warn_counts(report: dict) -> Counter:
    out: Counter = Counter()
    for label, eng in (report.get("engines") or {}).items():
        for f in eng.get("findings", ()):
            if f.get("severity") == "warn":
                out[f"{label}/{f.get('rule')}"] += 1
    return out


def diff(fresh: dict, baseline: Optional[dict]) -> List[str]:
    """Violations of the committed baseline ([] = clean)."""
    out: List[str] = []
    for label, eng in (fresh.get("engines") or {}).items():
        for f in eng.get("findings", ()):
            if f.get("severity") == "error":
                out.append(f"[{label}] {f.get('rule')}: {f.get('entry')}: "
                           f"{f.get('message')}")
    if baseline is None:
        out.append("no committed ANALYSIS.json baseline — run "
                   "`python -m repro.launch.analyze --write` and commit it")
        return out

    base_engines = baseline.get("engines") or {}
    fresh_engines = fresh.get("engines") or {}
    for label in sorted(set(base_engines) - set(fresh_engines)):
        out.append(f"engine `{label}` in baseline but missing from this "
                   "run — matrix shrank")

    for label, beng in sorted(base_engines.items()):
        feng = fresh_engines.get(label)
        if feng is None:
            continue
        blow: Dict[str, int] = beng.get("lowerings") or {}
        flow: Dict[str, int] = feng.get("lowerings") or {}
        for family, n in sorted(flow.items()):
            cap = blow.get(family)
            if cap is not None and n > cap:
                out.append(f"[{label}] lowerings for `{family}` grew "
                           f"{cap} -> {n} — bless with --write if "
                           "intentional")

    fwarn, bwarn = _warn_counts(fresh), _warn_counts(baseline)
    for key, n in sorted(fwarn.items()):
        cap = bwarn.get(key, 0)
        if n > cap:
            out.append(f"warning count for `{key}` grew {cap} -> {n}")
    return out


def is_stale(fresh: dict, baseline: Optional[dict]) -> List[str]:
    """Things in the fresh run the baseline does not know about yet."""
    if baseline is None:
        return ["no baseline committed"]
    out: List[str] = []
    base_engines = baseline.get("engines") or {}
    for label, feng in sorted((fresh.get("engines") or {}).items()):
        beng = base_engines.get(label)
        if beng is None:
            out.append(f"engine `{label}` not in baseline")
            continue
        for family in sorted(set(feng.get("lowerings") or {})
                             - set(beng.get("lowerings") or {})):
            out.append(f"[{label}] new entry family `{family}` not in "
                       "baseline")
    return out


def check_artifact(path: Optional[str] = None) -> dict:
    """Light gate for other tools (kernel_bench --smoke): the committed
    ANALYSIS.json must exist and carry zero error findings."""
    report = load(path)
    if report is None:
        raise AssertionError(
            "ANALYSIS.json missing — run `python -m repro.launch.analyze "
            "--write` and commit the artifact")
    errors = (report.get("summary") or {}).get("errors")
    if errors != 0:
        raise AssertionError(
            f"committed ANALYSIS.json records {errors} hot-path error(s) — "
            "fix them (or re-run `analyze --write` after fixing) before "
            "benchmarking")
    return report
