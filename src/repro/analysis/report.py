"""swatlint orchestration: trace an engine's matrix, run every rule,
assemble the serializable report that becomes ANALYSIS.json.

Report shape (one dict per analyzed engine, merged by the CLI):

  {"entries":   {name: {family, compile_key, carry_bytes, donated,
                        alias_pairs, collectives, wire_bytes, ...}},
   "lowerings": {family: distinct-compile-key count},
   "budgets":   {family: blessed CollectiveBudget (TP engines only)},
   "findings":  [Finding...],
   "summary":   {"errors": n, "warnings": n, "entries": n}}
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.analysis import rules as R
from repro.analysis import tracer as T
from repro.distributed.hlo_analysis import (CollectiveBudget,
                                            parse_collectives)


def analyze_entry_points(points: Sequence[T.EntryPoint], *,
                         label: str = "",
                         compile: bool = True,
                         min_carry_bytes: int = R.DEFAULT_MIN_CARRY_BYTES,
                         baseline: Optional[dict] = None,
                         pad_events: Optional[Sequence[dict]] = None
                         ) -> Dict[str, Any]:
    """Trace + lint a set of entry points; returns the per-engine report.

    baseline: this engine's section of a previously committed ANALYSIS.json.
    When present, TP collective budgets and per-family lowering caps come
    from it (check mode); when absent, the measured profile is blessed as
    the new budget (write mode).
    """
    base_budgets = (baseline or {}).get("budgets") or {}
    base_lowerings = (baseline or {}).get("lowerings") or {}

    traced: List[T.TracedEntry] = []
    findings: List[R.Finding] = []
    entries: Dict[str, Any] = {}
    budgets: Dict[str, dict] = {}

    for p in points:
        tr = T.trace(p, compile=compile)
        traced.append(tr)
        findings += R.check_donation(tr, min_bytes=min_carry_bytes)
        findings += R.check_host_sync(tr)
        findings += R.check_dtype_promotion(tr)
        findings += R.check_telemetry(tr)

        stats = (parse_collectives(tr.compiled_hlo)
                 if tr.compiled_hlo is not None else None)
        budget = R.budget_for(tr, base_budgets)
        if budget is not None:
            findings += R.check_collectives(tr, budget)
        elif stats is not None:
            # bless mode: record measured profile (+headroom) as the budget
            prev = budgets.get(p.family)
            cand = CollectiveBudget.from_counts(stats.counts,
                                               stats.wire_bytes)
            if prev is None or cand.max_wire_bytes > prev["max_wire_bytes"]:
                merged = dict((prev or {}).get("allow", {}))
                for k, n in cand.to_dict()["allow"].items():
                    merged[k] = max(merged.get(k, 0), n)
                budgets[p.family] = {
                    "allow": merged,
                    "max_wire_bytes": max(cand.max_wire_bytes,
                                          (prev or {}).get(
                                              "max_wire_bytes", 0.0))}

        carry = set(p.carries)
        donated_ok = all(l.index in tr.donated for l in tr.in_leaves
                         if l.argnum in carry) if carry else None
        entries[p.name] = {
            "family": p.family,
            "tags": sorted(p.tags),
            "compile_key": tr.compile_key,
            "carry_bytes": tr.carry_bytes,
            "carries_donated": donated_ok,
            "alias_pairs": len(tr.alias_pairs),
            "collectives": stats.counts if stats else {},
            "wire_bytes": stats.wire_bytes if stats else 0.0,
        }

    caps = {f: int(n) for f, n in base_lowerings.items()} or None
    findings += R.audit_recompiles(traced, max_per_family=caps)

    for ev in pad_events or ():
        if ev.get("kind") == "paged_gather":
            findings.append(R.Finding(
                "paged_gather_fallback", R.WARN, label or "kernels",
                f"paged decode materializes a {ev.get('nb')}x"
                f"{ev.get('page')}-row gather-view outside the kernel "
                "(pool-sized copy per step; an in-kernel block gather "
                "would remove it)",
                dict(ev)))
        else:
            findings.append(R.Finding(
                "pad_fallback", R.WARN, label or "kernels",
                f"decode_block_kv window {ev.get('w')} pads block_kv "
                f"{ev.get('block_kv')} -> {ev.get('chosen_block', ev.get('min_block'))}"
                " — odd window sizes waste KV bandwidth on the hot path",
                dict(ev)))

    errors = sum(1 for f in findings if f.severity == R.ERROR)
    warns = sum(1 for f in findings if f.severity == R.WARN)
    return {
        "entries": entries,
        "lowerings": R.lowering_counts(traced),
        "budgets": budgets or (base_budgets if base_budgets else {}),
        "findings": [f.to_dict() for f in findings],
        "summary": {"errors": errors, "warnings": warns,
                    "entries": len(entries)},
    }


def analyze_engine(engine, *, label: str,
                   baseline: Optional[dict] = None,
                   compile: bool = True) -> Dict[str, Any]:
    """Full swatlint pass over one live ServingEngine."""
    from repro.kernels import swat_decode

    swat_decode.consume_pad_events()          # drop stale events
    points = T.engine_entry_points(engine)
    # Tracing lowers the decode kernels, which re-emits pad events if the
    # engine config's window does not tile _MIN_BLOCK_KV.
    report = analyze_entry_points(
        points, label=label, compile=compile, baseline=baseline,
        pad_events=swat_decode.consume_pad_events())
    return report


def merge_reports(per_engine: Dict[str, dict], *, meta: dict) -> dict:
    """Combine per-engine reports into the ANALYSIS.json document."""
    total_err = sum(r["summary"]["errors"] for r in per_engine.values())
    total_warn = sum(r["summary"]["warnings"] for r in per_engine.values())
    total_entries = sum(r["summary"]["entries"] for r in per_engine.values())
    return {
        "swatlint": 1,
        "meta": meta,
        "engines": per_engine,
        "summary": {"errors": total_err, "warnings": total_warn,
                    "entries": total_entries},
    }
