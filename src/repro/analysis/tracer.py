"""Abstract tracing of jitted serving entry points (no real compute).

Every invariant swatlint enforces is a property of a PROGRAM, not of a run:
whether a decode-scan carry is donated, whether a callback hides inside the
scan body, how many collectives the partitioned module emits. So the tracer
never materializes an array — each entry point is traced on
`jax.ShapeDtypeStruct`s through three progressively lower views:

  jaxpr          `fn.trace(*avals).jaxpr` — the rule walkers' input
                 (host callbacks, dtype promotion, transfers in loop bodies)
  StableHLO      `fn.lower(*avals).as_text()` — carries the DONATION INTENT
                 (`tf.aliasing_output` / `jax.buffer_donor` arg attributes)
  compiled HLO   `lowered.compile().as_text()` — the ground truth: the
                 executable's `input_output_alias` table (donation that XLA
                 actually honored) and the post-SPMD collective instructions

The registry half of this module mirrors `serving/engine.py._Compiled`
exactly: for a live `ServingEngine` it rebuilds the abstract arguments each
jitted entry point is called with in production, so the analyzer's matrix IS
the serving matrix, not a parallel approximation that can drift.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_JAXPR_TYPES: Tuple[type, ...] = ()
for _mod in ("jax.extend.core", "jax.core"):
    try:
        import importlib

        _m = importlib.import_module(_mod)
        _JAXPR_TYPES += tuple(
            t for t in (getattr(_m, "Jaxpr", None),
                        getattr(_m, "ClosedJaxpr", None)) if t is not None)
    except Exception:  # pragma: no cover - version skew
        pass
_JAXPR_TYPES = tuple(dict.fromkeys(_JAXPR_TYPES))


@dataclasses.dataclass(frozen=True)
class LeafInfo:
    """One flattened input/output leaf of an entry point."""
    index: int                  # flat position across the whole arg list
    argnum: int                 # which top-level argument it belongs to
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    weak_type: bool = False


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One jitted serving entry point plus the abstract args it is served
    with in production.

    carries: argnums whose buffers the engine feeds back next call (ring
    caches, chunk logits) — the donation rule requires every leaf of these
    donated AND aliased in the compiled executable.
    tags: rule routing — "decode_hot_path" entries hold the strictest
    budgets; "slot_parallel"/"tp"/"single" pick the collective budget.
    """
    name: str
    family: str
    fn: Any
    args: Tuple[Any, ...]
    carries: Tuple[int, ...] = ()
    tags: frozenset = frozenset()


@dataclasses.dataclass
class TracedEntry:
    point: EntryPoint
    jaxpr: Any                        # ClosedJaxpr of the function body
    stablehlo: str
    compiled_hlo: Optional[str]
    in_leaves: List[LeafInfo]
    out_leaves: List[LeafInfo]
    donated: Set[int]                 # flat input indices marked donated
    pruned: Set[int]                  # flat indices dropped by keep_unused
    alias_pairs: List[Tuple[int, int]]  # (input_param, output_index) pairs
    compile_key: str

    def arg_leaves(self, argnum: int) -> List[LeafInfo]:
        return [l for l in self.in_leaves if l.argnum == argnum]

    @property
    def carry_bytes(self) -> int:
        carry = set(self.point.carries)
        return sum(l.nbytes for l in self.in_leaves if l.argnum in carry)


# ---------------------------------------------------------------- parsing --

_DONATE_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")
_ARG_RE = re.compile(r"%arg(\d+):")
_TENSOR_RE = re.compile(r"tensor<((?:\d+x)*)([A-Za-z][A-Za-z_0-9]*)>")
_MLIR_DTYPE = {
    "f64": "float64", "f32": "float32", "f16": "float16",
    "bf16": "bfloat16", "i1": "bool", "i8": "int8", "i16": "int16",
    "i32": "int32", "i64": "int64", "ui8": "uint8", "ui16": "uint16",
    "ui32": "uint32", "ui64": "uint64",
}


@dataclasses.dataclass(frozen=True)
class MainArg:
    """One @main argument of a lowered StableHLO module."""
    index: int
    shape: Tuple[int, ...]
    dtype: str                  # numpy-style name ("" if unrecognized)
    donated: bool


def stablehlo_main_args(stablehlo: str) -> List[MainArg]:
    """Parse @main's signature: per-arg shape/dtype + donation marker
    (`tf.aliasing_output` / `jax.buffer_donor`)."""
    m = re.search(r"func\.func\s+(?:public\s+)?@main\(", stablehlo)
    if m is None:
        return []
    i, depth = m.end(), 1
    while i < len(stablehlo) and depth:
        c = stablehlo[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    sig = stablehlo[m.end():i]
    args = list(_ARG_RE.finditer(sig))
    out: List[MainArg] = []
    for j, am in enumerate(args):
        end = args[j + 1].start() if j + 1 < len(args) else len(sig)
        span = sig[am.start():end]
        tm = _TENSOR_RE.search(span)
        shape: Tuple[int, ...] = ()
        dtype = ""
        if tm:
            dims = tm.group(1)
            shape = tuple(int(d) for d in dims.split("x") if d)
            dtype = _MLIR_DTYPE.get(tm.group(2), "")
        out.append(MainArg(
            index=int(am.group(1)), shape=shape, dtype=dtype,
            donated=any(k in span for k in _DONATE_MARKERS)))
    return out


def align_main_args(in_leaves: List[LeafInfo],
                    main_args: List[MainArg]) -> Dict[int, int]:
    """Map StableHLO @main arg index -> flat input leaf index.

    jit lowers with keep_unused=False, so leaves that do not reach an
    output are PRUNED from the module signature — @main arg numbering is
    the flat numbering with holes closed up. Both sequences preserve
    order, so a greedy forward match on (shape, dtype) recovers the map.
    """
    out: Dict[int, int] = {}
    li = 0
    for a in main_args:
        while li < len(in_leaves):
            leaf = in_leaves[li]
            if leaf.shape == a.shape and (not a.dtype
                                          or leaf.dtype == a.dtype):
                out[a.index] = leaf.index
                li += 1
                break
            li += 1
    return out


def donated_arg_indices(stablehlo: str) -> Set[int]:
    """@main arg indices (module numbering) carrying a donation marker."""
    return {a.index for a in stablehlo_main_args(stablehlo) if a.donated}


def compiled_alias_pairs(hlo_text: str) -> List[Tuple[int, int]]:
    """(input_param, output_index) pairs from the executable's
    `input_output_alias={ {out}: (in, {}, may-alias), ... }` header — the
    proof that XLA kept a donation rather than silently copying."""
    m = re.search(r"input_output_alias=\{", hlo_text)
    if m is None:
        return []
    i, depth, start = m.end(), 1, m.end()
    while i < len(hlo_text) and depth:
        if hlo_text[i] == "{":
            depth += 1
        elif hlo_text[i] == "}":
            depth -= 1
        i += 1
    body = hlo_text[start:i - 1]
    pairs = []
    for out_idx, in_idx in re.findall(r"\{([\d,\s]*)\}:\s*\((\d+)", body):
        first = out_idx.split(",")[0].strip()
        pairs.append((int(in_idx), int(first) if first else 0))
    return pairs


def _leafinfos(tree, argnums: Optional[Sequence[int]] = None
               ) -> List[LeafInfo]:
    """Flatten a pytree (or tuple of per-arg pytrees) into LeafInfo rows."""
    rows: List[LeafInfo] = []
    if argnums is None:                       # single pytree (outputs)
        groups = [(0, tree)]
    else:
        groups = list(zip(argnums, tree))
    idx = 0
    for argnum, sub in groups:
        for leaf in jax.tree.leaves(sub):
            dt = jnp.dtype(leaf.dtype)
            rows.append(LeafInfo(
                index=idx, argnum=argnum, shape=tuple(leaf.shape),
                dtype=str(dt), nbytes=int(np.prod(leaf.shape, dtype=np.int64)
                                          or 1) * dt.itemsize,
                weak_type=bool(getattr(leaf, "weak_type", False))))
            idx += 1
    return rows


def _compile_key(family: str, in_leaves: List[LeafInfo]) -> str:
    sig = tuple((l.shape, l.dtype, l.weak_type) for l in in_leaves)
    return hashlib.sha1(repr((family, sig)).encode()).hexdigest()[:12]


def sub_jaxprs(params: Dict[str, Any]):
    """Nested jaxprs inside an eqn's params (scan/while/cond/pjit bodies),
    robust to where a given jax version hides them."""
    for v in params.values():
        if isinstance(v, _JAXPR_TYPES):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, _JAXPR_TYPES):
                    yield x


def walk_jaxpr(closed, visit, _ctx: Tuple[str, ...] = ()):
    """visit(eqn, ctx) over every equation, recursing into sub-jaxprs with
    the enclosing primitive names as ctx (so rules can ask 'inside scan?')."""
    jaxpr = getattr(closed, "jaxpr", closed)
    for eqn in jaxpr.eqns:
        visit(eqn, _ctx)
        for sub in sub_jaxprs(eqn.params):
            walk_jaxpr(sub, visit, _ctx + (eqn.primitive.name,))


# ---------------------------------------------------------------- tracing --

def trace(point: EntryPoint, *, compile: bool = True) -> TracedEntry:
    """Trace + lower (+ compile) one entry point on its abstract args."""
    fn = point.fn
    if not hasattr(fn, "lower"):
        fn = jax.jit(fn)
    try:
        jaxpr = fn.trace(*point.args).jaxpr
    except AttributeError:                     # older jax: no JitWrapped.trace
        jaxpr = jax.make_jaxpr(fn)(*point.args)
    lowered = fn.lower(*point.args)
    stablehlo = lowered.as_text()
    compiled_hlo = lowered.compile().as_text() if compile else None
    in_leaves = _leafinfos(point.args, range(len(point.args)))
    out_leaves = _leafinfos(jax.eval_shape(fn, *point.args))
    # @main numbering skips pruned (unused) leaves — map donation markers
    # and compiled alias params back into flat leaf space. A pruned leaf is
    # never materialized, so "pruned" counts as donated for rule purposes.
    main_args = stablehlo_main_args(stablehlo)
    to_flat = align_main_args(in_leaves, main_args)
    kept_flat = set(to_flat.values())
    pruned = {l.index for l in in_leaves if l.index not in kept_flat}
    donated = {to_flat[a.index] for a in main_args
               if a.donated and a.index in to_flat}
    donated |= pruned
    alias_pairs = []
    if compiled_hlo:
        alias_pairs = [(to_flat.get(i, i), o)
                       for i, o in compiled_alias_pairs(compiled_hlo)]
    return TracedEntry(
        point=point,
        jaxpr=jaxpr,
        stablehlo=stablehlo,
        compiled_hlo=compiled_hlo,
        in_leaves=in_leaves,
        out_leaves=out_leaves,
        donated=donated,
        pruned=pruned,
        alias_pairs=alias_pairs,
        compile_key=_compile_key(point.family, in_leaves),
    )


# ------------------------------------------------- serving entry registry --

def engine_tags(engine) -> frozenset:
    if engine.mesh is None:
        return frozenset({"single"})
    model = dict(getattr(engine.mesh, "shape", {})).get("model", 1)
    return frozenset({"tp"} if model > 1 else {"slot_parallel"})


def engine_entry_points(engine, *, batch_sizes: Optional[Sequence[int]] = None,
                        scan_lens: Optional[Sequence[int]] = None,
                        prefill_len: int = 64,
                        chunk_len: int = 32) -> List[EntryPoint]:
    """The abstract serving matrix for one engine: every jitted entry point
    `_Compiled` serves, with the exact argument avals `ServingEngine` feeds
    it. batch_sizes are prefill-row counts (default: 1 and the full slot
    count); scan_lens are decode-block lengths (default: 1 and scan_steps).
    """
    from repro.core import model as Mod

    c = engine._c
    cfg = engine.cfg
    slots = engine.slots
    paged = bool(getattr(c, "paged", False))
    base = engine_tags(engine) | ({"paged"} if paged else frozenset())
    v = cfg.vocab_size
    if batch_sizes is None:
        batch_sizes = sorted({1, slots})
    if scan_lens is None:
        scan_lens = sorted({1, engine.scan_steps})

    params_sds = jax.eval_shape(
        lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    def caches_sds(n):
        return jax.eval_shape(
            lambda: Mod.init_caches(cfg, n, engine.max_len,
                                    lookahead=c.lookahead))

    def slot_caches_sds():
        """Resident slot-cache avals: paged engines hold the block pool +
        tables, contiguous engines the per-slot rings. Prefill-row caches
        (caches_sds) stay contiguous either way — admission converts."""
        if not paged:
            return caches_sds(slots)
        return jax.eval_shape(
            lambda: Mod.init_paged_caches(cfg, slots, engine.max_len,
                                          lookahead=c.lookahead,
                                          shared_pool=engine.mesh is None))

    def sds(shape, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(shape, dtype)

    if paged:
        layout = Mod.paged_layout(cfg, engine.max_len, c.lookahead)
        tables_sds = {f"l{i}": sds((slots, geo["nb"]))
                      for i, geo in layout.items()}

    points: List[EntryPoint] = []
    for n in batch_sizes:
        points.append(EntryPoint(
            name=f"prefill[n={n},len={prefill_len}]", family="prefill",
            fn=c.prefill(n),
            args=(params_sds, sds((n, prefill_len)), sds((n,))),
            tags=base))
        if Mod.prefill_chunkable(cfg):
            points.append(EntryPoint(
                name=f"prefill_chunk[n={n},c={chunk_len}]",
                family="prefill_chunk", fn=c.chunk(n),
                args=(params_sds, caches_sds(n), sds((n, chunk_len)),
                      sds(()), sds((n,)), sds((n, v), jnp.float32)),
                carries=(1, 5), tags=base))
        if paged:
            points.append(EntryPoint(
                name=f"cache_insert_paged[slots={slots},n={n}]",
                family="cache_insert_paged", fn=c.insert_paged(slots, n),
                args=(slot_caches_sds(), caches_sds(n), sds((n,)),
                      tables_sds),
                carries=(0,), tags=base))
        else:
            points.append(EntryPoint(
                name=f"cache_insert[slots={slots},n={n}]",
                family="cache_insert", fn=c.insert(slots, n),
                args=(caches_sds(slots), caches_sds(n), sds((n,))),
                carries=(0,), tags=base))
        points.append(EntryPoint(
            name=f"sample[n={n}]", family="sample", fn=c.sample(n),
            args=(key_sds, sds((n, v), jnp.float32),
                  sds((n,), jnp.float32)),
            tags=base))

    hot = base | {"decode_hot_path"}
    if paged:
        # the COW/table-push maintenance dispatch runs BETWEEN decode
        # blocks — it shares the hot-path contract (donated pool, zero
        # collectives). COW moves (m>0) only exist on the shared pool:
        # under a mesh the pool is local-id, every block is exclusively
        # owned, and the engine only ever dispatches the m=0 table push.
        m = 4 if engine.mesh is None else 0
        mv = {k: sds((m,)) for k in tables_sds} if m else {}
        points.append(EntryPoint(
            name=f"cache_fixup[slots={slots},m={m}]", family="cache_fixup",
            fn=c.fixup(slots, m),
            args=(slot_caches_sds(), tables_sds, mv, mv),
            carries=(0,), tags=hot))
    # the scan signatures carry the resilience state: a (slots,) bool
    # poisoned flag always, plus the fault-injection countdown vector when
    # the engine's FaultPlan compiles logit faults in — tracing the guarded
    # (and, for chaos engines, injected) programs is how the baseline pins
    # "guards add zero collectives / host syncs" to the hot path
    fin = ((sds((slots,)),) if c.faults.has_logit_faults else ())
    # metrics engines carry the telemetry counter pytree as the LAST scan
    # argument; it is a carry like the caches (donated, fed back verbatim)
    # and tagging "metrics" routes the telemetry rule family at it
    from repro.telemetry import metrics as _MX
    metrics_on = bool(getattr(c, "metrics", False))
    mx = (({**{k: sds((slots,)) for k in _MX.PER_SLOT},
            **{k: sds(()) for k in _MX.SCALARS}},) if metrics_on else ())
    hot = hot | {"metrics"} if metrics_on else hot
    for n in scan_lens:
        if engine.speculative:
            drafter = c.drafter
            args = (params_sds, slot_caches_sds(), sds((slots,)),
                    sds((slots,), jnp.bool_), sds((slots,)),
                    sds((slots,), jnp.float32), sds((), jnp.bool_),
                    key_sds, sds((slots, drafter.history)),
                    sds((slots,)), sds((slots,), jnp.bool_)) + fin + mx
            points.append(EntryPoint(
                name=f"spec_scan[n={n},slots={slots}]", family="spec_scan",
                fn=c.spec_scan(n, slots), args=args,
                carries=(1,) + ((len(args) - 1,) if metrics_on else ()),
                tags=hot))
        else:
            args = (params_sds, slot_caches_sds(), sds((slots,)),
                    sds((slots,), jnp.bool_), sds((slots,)),
                    sds((slots,), jnp.float32), sds((), jnp.bool_),
                    key_sds, sds((slots,), jnp.bool_)) + fin + mx
            points.append(EntryPoint(
                name=f"scan[n={n},slots={slots}]", family="scan",
                fn=c.scan(n, slots), args=args,
                carries=(1,) + ((len(args) - 1,) if metrics_on else ()),
                tags=hot))
    return points
