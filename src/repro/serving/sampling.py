"""Per-slot token sampling for the serving engine.

One jitted function covers the whole batch: each slot carries its own
temperature (a traced (B,) vector, so mixing greedy and sampling requests
never retraces), greedy rows take argmax, sampling rows draw from the
temperature-scaled (optionally top-k-truncated) distribution. This is where
the seed engine's bug lived — `step()` passed a hard-coded 0.0 instead of
each slot's `Request.temperature`; the engine now threads the per-slot
vector through every prefill and decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def finite_rows(logits):
    """(B,) bool: row is entirely finite (no NaN/Inf anywhere in its
    trailing axes). The decode scan's numerical guard: a poisoned row's
    argmax/categorical output is garbage, so the engine quarantines the
    slot instead of emitting it. Strictly row-wise (like every sampling op
    here) — the reduction runs over the local vocab/position axes only, so
    under a slot-sharded mesh it adds ZERO collectives, and healthy rows'
    tokens are bitwise unchanged by the check existing."""
    return jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))


def sample(key, logits, temperatures, top_k: int = 0, any_sampling=None):
    """Draw one token per row. logits: (B, V); temperatures: (B,) — rows
    with temperature <= 0 are greedy. top_k: static int, 0 disables.
    any_sampling: optional scalar bool, the precomputed `any(temps > 0)`
    predicate. Under a slot-sharded mesh the in-place reduction lowers to
    a pred[] all-reduce; a caller that already knows the answer (the
    engine stages slot temperatures from host state) passes it here and
    keeps the decode scan collective-free. Either way the chosen branch —
    and therefore every token — is identical.

    The categorical draw consumes the same randomness whatever the active
    mask or temperatures are, so a scan-decode loop and a stepwise loop that
    split keys identically produce identical tokens.

    All-greedy batches take a runtime `lax.cond` fast path: one argmax and
    none of the top-k / gumbel ops. Greedy rows argmax the RAW logits (the
    same value the slow path's final `where` picks), so the branch is
    bitwise-invisible — it exists because on small models the sampling op
    chain costs as much as the forward pass it follows, and the speculative
    verify runs it at every one of T positions."""
    logits = logits.astype(jnp.float32)
    temperatures = jnp.asarray(temperatures, jnp.float32)

    def greedy_only(lg):
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def full(lg):
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        if top_k and top_k < lg.shape[-1]:
            kth = jax.lax.top_k(lg, top_k)[0][:, -1:]
            lg = jnp.where(lg < kth, -jnp.inf, lg)
        scaled = lg / jnp.maximum(temperatures[:, None], 1e-6)
        drawn = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
        return jnp.where(temperatures > 0, drawn, greedy)

    if any_sampling is None:
        any_sampling = jnp.any(temperatures > 0)
    return jax.lax.cond(any_sampling, full, greedy_only, logits)
