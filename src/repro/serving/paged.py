"""Host-side paged-KV bookkeeping: block allocator, radix-trie prefix
matcher, and the per-engine PagedManager.

The device side (`core/layers.py` paged gather/scatter, `core/model.py`
init_paged_caches) only ever sees block TABLES; everything about which
block belongs to whom — refcounts, copy-on-write, prefix sharing, parking
freed slots on their scratch block — lives here, in plain numpy/python, and
is pushed to the device as whole tables at insert/fixup boundaries.

Consistency contract with the decode scan: the scan writes rings
unconditionally for every row (dead rows included), so device tables may
lag the host mirror ONLY where the lagging writes land in blocks the host
considers free or scratch. Freeing a slot therefore parks its table on the
slot's reserved scratch block before its real blocks are released, and
every admission pushes the full table tensor atomically in the same
dispatch that writes the new rows.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class BlockAllocator:
    """Refcounted free-list allocator over a fixed pool of block ids.

    Invariants (property-tested in tests/test_paged.py):
      - refcounts are never negative: releasing a free block raises
      - the free list never double-holds an id: alloc never returns a block
        that is still referenced
      - reserved ids (per-slot scratch blocks) are never handed out
    """

    def __init__(self, num_blocks: int, reserved: Iterable[int] = ()):
        self.num_blocks = num_blocks
        self.reserved = frozenset(reserved)
        self._ref: Dict[int, int] = {}
        self._free = deque(b for b in range(num_blocks)
                           if b not in self.reserved)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("block pool exhausted")
        bid = self._free.popleft()
        self._ref[bid] = 1
        return bid

    def retain(self, bid: int) -> None:
        if self._ref.get(bid, 0) <= 0:
            raise RuntimeError(f"retain of unallocated block {bid}")
        self._ref[bid] += 1

    def release(self, bid: int) -> None:
        ref = self._ref.get(bid, 0)
        if ref <= 0:
            raise RuntimeError(f"double free of block {bid}")
        ref -= 1
        self._ref[bid] = ref
        if ref == 0:
            del self._ref[bid]
            self._free.append(bid)

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    @property
    def allocated(self) -> int:
        """Distinct blocks currently referenced (the cache-bytes metric)."""
        return len(self._ref)

    @property
    def free_count(self) -> int:
        return len(self._free)


class RadixTrie:
    """Compressed radix trie over token sequences — the scheduler's prefix
    matcher. Edges are labeled with token runs; insertion splits edges at
    divergence points, so `longest_prefix` walks at most O(match length)
    tokens regardless of how many prompts are indexed."""

    def __init__(self):
        # first-token -> [label list, child dict]; a dict per node
        self._root: Dict[int, list] = {}
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def insert(self, tokens: Sequence[int]) -> None:
        toks = list(tokens)
        self._count += 1
        node = self._root
        i = 0
        while i < len(toks):
            head = toks[i]
            if head not in node:
                node[head] = [toks[i:], {}]
                return
            edge = node[head]
            label = edge[0]
            j = 0
            while (j < len(label) and i + j < len(toks)
                   and label[j] == toks[i + j]):
                j += 1
            if j < len(label):
                # diverged mid-edge: split the edge at j
                rest = label[j:]
                edge[0] = label[:j]
                edge[1] = {rest[0]: [rest, edge[1]]}
            i += j
            node = edge[1]
        # exact prefix of an existing sequence: nothing further to add

    def longest_prefix(self, tokens: Sequence[int]) -> int:
        """Length of the longest common prefix between `tokens` and any
        inserted sequence."""
        toks = list(tokens)
        node = self._root
        i = 0
        while i < len(toks) and toks[i] in node:
            label, child = node[toks[i]]
            j = 0
            while (j < len(label) and i + j < len(toks)
                   and label[j] == toks[i + j]):
                j += 1
            i += j
            if j < len(label):
                break
            node = child
        return i


def batch_lcp(prompts: Sequence[Sequence[int]]) -> int:
    """Longest prefix shared by EVERY prompt in the batch, via the trie:
    insert the first, then the running LCP can only shrink to each later
    prompt's match length."""
    if len(prompts) < 2:
        return 0
    trie = RadixTrie()
    trie.insert(prompts[0])
    lcp = len(prompts[0])
    for p in prompts[1:]:
        lcp = min(lcp, trie.longest_prefix(p))
        if lcp == 0:
            return 0
    return lcp


def _ring_slot(pos: np.ndarray, g: int, ring: int) -> np.ndarray:
    """Token position -> cache row, the FIFO formula shared with
    layers.ring_scatter: pinned globals [0, g), ring [g, g+ring)."""
    return np.where(pos < g, pos, g + (pos - g) % ring)


class PagedManager:
    """Block tables + allocators for one ServingEngine.

    layout: `model.paged_layout` output — {pattern index: {page, nb, cap,
    g, ring}}. mode "shared" runs the single-device global-id pool with
    true block sharing and copy-on-write; mode "local" (under a mesh) keeps
    per-slot local ids (pool sharded over slots, no cross-slot references)
    where only the parking machinery is active.
    """

    def __init__(self, layout: Dict[int, Dict[str, int]], slots: int,
                 mode: str = "shared"):
        assert mode in ("shared", "local"), mode
        self.layout = layout
        self.slots = slots
        self.mode = mode
        self.tables: Dict[int, np.ndarray] = {}
        self.alloc: Dict[int, BlockAllocator] = {}
        self.parked = np.ones((slots,), bool)   # all slots start free
        self.dirty = True                       # device tables not yet pushed
        for i, geo in layout.items():
            nb = geo["nb"]
            if mode == "shared":
                nbp = nb + 1
                scratch = {s * nbp + nb for s in range(slots)}
                self.alloc[i] = BlockAllocator(slots * nbp, reserved=scratch)
                self.tables[i] = np.stack(
                    [np.full((nb,), self.scratch_id(i, s), np.int32)
                     for s in range(slots)])
            else:
                self.tables[i] = np.full((slots, nb), nb, np.int32)

    def scratch_id(self, layer: int, slot: int) -> int:
        """The slot's reserved never-read block: parked tables point here so
        the scan's unconditional dead-row writes stay harmless."""
        if self.mode == "shared":
            nbp = self.layout[layer]["nb"] + 1
            return slot * nbp + self.layout[layer]["nb"]
        return self.layout[layer]["nb"]

    # ------------------------------------------------------------- admit --

    def admit(self, slot_ids: Sequence[int], lengths: Sequence[int],
              prefix_len: int = 0) -> None:
        """Assign blocks to freshly admitted slots. With prefix_len P > 0
        (shared mode, >= 2 rows) the first row becomes the leader and later
        rows reference every leader block the divergence can't touch: a
        block is shareable iff NO admitted row's suffix [P, len) writes any
        of its rows — untouched blocks hold pure prefix content (or pinned
        zeros), identical across the group by construction."""
        for s in slot_ids:
            assert self.parked[s], f"admitting occupied slot {s}"
        lengths = [int(x) for x in lengths]
        for i, geo in self.layout.items():
            nb, page, g, ring = geo["nb"], geo["page"], geo["g"], geo["ring"]
            if self.mode == "local":
                for s in slot_ids:
                    self.tables[i][s] = np.arange(nb, dtype=np.int32)
                continue
            share = prefix_len > 0 and len(slot_ids) >= 2
            shareable: set = set(range(nb)) if share else set()
            if share:
                for ln in lengths:
                    suffix = np.arange(prefix_len, ln, dtype=np.int64)
                    rows = _ring_slot(suffix, g, ring)
                    shareable -= set(np.unique(rows // page).tolist())
            alc = self.alloc[i]
            leader: Optional[np.ndarray] = None
            for s in slot_ids:
                row = np.empty((nb,), np.int32)
                for b in range(nb):
                    if leader is not None and b in shareable:
                        row[b] = leader[b]
                        alc.retain(int(leader[b]))
                    else:
                        row[b] = alc.alloc()
                self.tables[i][s] = row
                if share and leader is None:
                    leader = row
        for s in slot_ids:
            self.parked[s] = False
        self.dirty = False   # caller pushes full tables in the insert

    # -------------------------------------------------------------- free --

    def free(self, slot: int) -> None:
        """Release the slot's blocks and park its table on the scratch
        block. Safe to call on an already-parked slot (engine free paths
        can race retirement with quarantine)."""
        if self.parked[slot]:
            return
        for i in self.layout:
            if self.mode == "shared":
                for bid in self.tables[i][slot]:
                    self.alloc[i].release(int(bid))
            self.tables[i][slot] = self.scratch_id(i, slot)
        self.parked[slot] = True
        self.dirty = True

    # --------------------------------------------------------------- cow --

    def cow_moves(self, positions: Dict[int, int], span: int
                  ) -> Dict[int, List[Tuple[int, int]]]:
        """Copy-on-write plan for an upcoming decode block: every occupied
        slot s will write ring rows for token positions [positions[s],
        positions[s]+span); any block it references with refcount > 1 gets
        a private copy (src, dst) and the table mirror is repointed. The
        LAST sharer left at refcount 1 keeps the original block — no copy.
        Returns per-layer move lists (empty everywhere in local mode)."""
        moves: Dict[int, List[Tuple[int, int]]] = {i: [] for i in self.layout}
        if self.mode == "local":
            return moves
        for i, geo in self.layout.items():
            page, g, ring = geo["page"], geo["g"], geo["ring"]
            alc = self.alloc[i]
            for s, p0 in positions.items():
                if self.parked[s]:
                    continue
                pos = np.arange(p0, p0 + span, dtype=np.int64)
                blocks = np.unique(_ring_slot(pos, g, ring) // page)
                for b in blocks.tolist():
                    src = int(self.tables[i][s][b])
                    if alc.refcount(src) > 1:
                        dst = alc.alloc()
                        alc.release(src)
                        self.tables[i][s][b] = dst
                        moves[i].append((src, dst))
                        self.dirty = True
        return moves

    def force_private(self, slot: int) -> Dict[int, List[Tuple[int, int]]]:
        """COW every shared block of one slot (cache-poison injection needs
        the slot's blocks exclusively owned before NaN-ing them)."""
        moves: Dict[int, List[Tuple[int, int]]] = {i: [] for i in self.layout}
        if self.mode == "local" or self.parked[slot]:
            return moves
        for i in self.layout:
            alc = self.alloc[i]
            for b in range(self.layout[i]["nb"]):
                src = int(self.tables[i][slot][b])
                if alc.refcount(src) > 1:
                    dst = alc.alloc()
                    alc.release(src)
                    self.tables[i][slot][b] = dst
                    moves[i].append((src, dst))
                    self.dirty = True
        return moves

    # ------------------------------------------------------------- stats --

    def blocks_in_use(self) -> int:
        if self.mode == "local":
            return sum(geo["nb"] * int((~self.parked).sum())
                       for geo in self.layout.values())
        return sum(a.allocated for a in self.alloc.values())

    def blocks_total(self) -> int:
        return sum(geo["nb"] for geo in self.layout.values()) * self.slots

    def reset(self) -> None:
        """Back to the all-parked state (engine cache-loss fallback)."""
        self.__init__(self.layout, self.slots, self.mode)
