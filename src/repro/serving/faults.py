"""Deterministic fault injection for the serving engine (the chaos layer).

Resilience claims are only as good as the faults you can reproduce. This
module is the injection side of the engine's resilience contract: a
`FaultPlan` describes, ahead of time and in static terms, exactly which
slots get poisoned when — so a chaos run is a *program*, compiled once and
bitwise repeatable, not a monkeypatch race.

Three fault classes, three injection points:

  * numerical poison (`poison_logits`, `poison_cache`) — NaN/Inf planted in
    a chosen slot's logits at a chosen token index (compiled into the decode
    scan body as a countdown-vector `where`, so the injected program differs
    from production ONLY by that masked select and healthy slots stay
    bitwise identical), or smeared over a slot's ring K cache between blocks
    (exercising the guard's ability to catch corruption it didn't see born).
  * kernel failure (`fail_pallas_dispatch`) — the Pallas decode kernel
    raises `KernelDispatchError` at dispatch, driving the engine down the
    graceful-degradation ladder to the ref impl.
  * drafter corruption (`corrupt_draft_slots`) — a slot's speculative drafts
    are replaced with out-of-vocabulary garbage; `drafter.sanitize` must
    clip them so verification rejects the drafts instead of the gather
    silently clamping (jax OOB semantics) into plausible-but-wrong tokens.

`FaultPlan` is frozen/hashable on purpose: it is part of the engine's
compile identity (`_get_compiled`), like the drafter spec — two engines
differing only in faults get different programs, and `FaultPlan()` (the
default) compiles the production program with zero injection code.

The module also re-exports the engine's structured degradation-event
channel (`record_event`/`consume_events`) as a back-compat shim over the
unified telemetry bus (`repro.telemetry.events`): every quarantine,
fallback, rejection, and deadline expiry is recorded as a dict so tests,
benchmarks (`BENCH_serve.json` resilience section), and the `kernel_bench
--smoke` gate can assert "no degradation fired on a clean run" without
scraping logs — and engine tracers see the same stream through their bus
subscription (one stream, not two).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

NAN, INF = "nan", "inf"

# never-fires countdown sentinel: the in-scan trigger is `fin == 0` (or
# `0 <= fin < T` speculatively) and fin only ever decrements, so any
# negative stage value can never match again
NO_FAULT = np.int32(-(2 ** 30))


class KernelDispatchError(RuntimeError):
    """Simulated (or real) kernel dispatch failure — the engine catches it
    and falls back to the reference decode impl."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Static description of the faults to inject into one engine.

    poison_logits: ((slot, token_idx, "nan"|"inf"), ...) — replace the
        slot's whole logits row with the value at the decode step that
        would emit token number `token_idx` of that slot (0-based over the
        slot's output stream, so index 0 is the prefill-sampled token and
        indices >= 1 are decode steps; an index of 0 never fires — prefill
        sampling is outside the scan). Speculatively the whole (T,V) verify
        row is poisoned at the step whose emission window covers the index.
    poison_cache: ((slot, token_idx), ...) — overwrite the slot's ring K
        caches with NaN once the slot has emitted `token_idx` tokens
        (applied between decode blocks; the next step's attention propagates
        it into the logits where the in-scan guard catches it).
    corrupt_draft_slots: slots whose speculative drafts are replaced with
        out-of-vocab garbage inside the scan body.
    fail_pallas_dispatch: make the Pallas decode kernel raise
        `KernelDispatchError` at dispatch (armed at engine construction;
        call `clear_kernel_failure()` when done — module-global flag).
    """
    poison_logits: Tuple[Tuple[int, int, str], ...] = ()
    poison_cache: Tuple[Tuple[int, int], ...] = ()
    corrupt_draft_slots: Tuple[int, ...] = ()
    fail_pallas_dispatch: bool = False

    def __post_init__(self):
        for slot, idx, kind in self.poison_logits:
            assert kind in (NAN, INF), kind
            assert slot >= 0 and idx >= 0, (slot, idx)
        seen = [s for s, _, _ in self.poison_logits]
        assert len(seen) == len(set(seen)), (
            "one poison_logits entry per slot (the countdown vector holds "
            f"a single trigger index per slot): {self.poison_logits}")

    @property
    def any(self) -> bool:
        return bool(self.poison_logits or self.poison_cache
                    or self.corrupt_draft_slots or self.fail_pallas_dispatch)

    @property
    def has_logit_faults(self) -> bool:
        """True when the compiled scan body carries injection code (an
        extra countdown-vector argument + one masked select)."""
        return bool(self.poison_logits)

    # ------------------------------------------------------------ staging --
    def inf_mask(self, slots: int) -> np.ndarray:
        """(slots,) bool: True where the poison value is +inf (else nan).
        Static per plan — baked into the scan as a constant."""
        m = np.zeros((slots,), bool)
        for slot, _, kind in self.poison_logits:
            if slot < slots and kind == INF:
                m[slot] = True
        return m

    def draft_mask(self, slots: int) -> np.ndarray:
        """(slots,) bool: slots whose drafts are corrupted. Static."""
        m = np.zeros((slots,), bool)
        for slot in self.corrupt_draft_slots:
            if slot < slots:
                m[slot] = True
        return m

    def logit_countdown(self, slots: int, tokens_done,
                        fired=()) -> np.ndarray:
        """(slots,) int32 countdown the engine stages at the start of a
        decode block: `target_idx - tokens_done[slot]`, NO_FAULT where the
        slot has no pending trigger. The scan decrements it by each step's
        emission count, firing when it reaches zero. `fired` lists slots
        whose fault already went off — each entry targets the slot's
        first occupant only, so a request admitted into the quarantined
        slot afterwards decodes clean."""
        fin = np.full((slots,), NO_FAULT, np.int32)
        for slot, idx, _ in self.poison_logits:
            if slot < slots and slot not in fired:
                rem = idx - int(tokens_done[slot])
                fin[slot] = rem if rem > 0 else NO_FAULT
        return fin

    def cache_poisons_due(self, slots: int, tokens_done, applied) -> list:
        """Slots whose ring caches are due for poisoning: emitted at least
        `token_idx` tokens and not in `applied` yet."""
        return [s for s, idx in self.poison_cache
                if s < slots and s not in applied
                and int(tokens_done[s]) >= idx]


# ------------------------------------------------- degradation event bus --
# Back-compat shim: the degradation stream now lives on the UNIFIED
# telemetry bus (`repro.telemetry.events`) so engine tracers, benches,
# and tests all read one stream, not two. These re-exports keep every
# historical `faults.record_event` / `faults.consume_events` call site
# working; the old module-local `_EVENTS` list (the duplicate consume
# path) is deleted — recording here and draining from telemetry (or vice
# versa) observe the same queue.

from repro.telemetry.events import (consume_events,  # noqa: F401,E402
                                    peek_events, record_event)


# ------------------------------------------------- simulated kernel fault --

def install_kernel_failure() -> None:
    """Arm the Pallas decode kernel to raise `KernelDispatchError` on its
    next dispatch. Module-global (covers every engine in the process) —
    pair with `clear_kernel_failure()` in a finally block."""
    from repro.kernels import swat_decode as K
    K.set_force_fail(True)


def clear_kernel_failure() -> None:
    from repro.kernels import swat_decode as K
    K.set_force_fail(False)


# ------------------------------------------------------ malformed inputs --

def malformed_prompts(vocab_size: int, *, oversize: int = 0,
                      seed: int = 0) -> List[Tuple[np.ndarray, str]]:
    """Deterministic adversarial prompt corpus: (prompt, expected-flavor)
    pairs the scheduler must REJECT per-request (never raise). `oversize`
    > 0 adds a prompt longer than that bound (pair with the engine's
    `max_prompt_len` knob)."""
    rng = np.random.RandomState(seed)
    out: List[Tuple[np.ndarray, str]] = [
        (np.zeros((0,), np.int32), "empty"),
        (np.zeros((3, 0), np.int32), "empty"),
        (np.asarray([1, vocab_size + 7, 2], np.int32), "token id"),
        (np.asarray([-4, 1, 2], np.int32), "token id"),
    ]
    if oversize:
        out.append((rng.randint(0, vocab_size, (oversize + 1,))
                    .astype(np.int32), "longer than"))
    return out
