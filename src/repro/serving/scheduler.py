"""Admission scheduling for the continuous-batching engine.

The scheduler turns the pending FCFS queue into one padded, batched prefill
call: take as many waiting prompts as there are free slots, right-pad them
to a shared bucketed length, and stop early if the padded token count would
blow the prefill budget (the VMEM bound — prefill score memory scales with
padded tokens; the engine additionally chunks long batches along the
sequence axis). Bucketing pad lengths to `pad_to` multiples keeps the jit
cache small: the prefill function retraces per (rows, padded_len) pair only.
"""
from __future__ import annotations

import dataclasses
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class PrefillPlan:
    """One batched prefill: `tokens` (n, L_pad) right-padded int32 prompts
    for `requests`, with per-row real `lengths` (n,)."""
    requests: List
    tokens: np.ndarray
    lengths: np.ndarray


class Scheduler:
    def __init__(self, *, max_prefill_tokens: int = 8192, pad_to: int = 16):
        assert pad_to >= 1 and max_prefill_tokens >= pad_to
        self.max_prefill_tokens = max_prefill_tokens
        self.pad_to = pad_to

    def _bucket(self, n: int) -> int:
        return -(-max(n, 1) // self.pad_to) * self.pad_to

    def plan(self, pending: Deque, num_free: int) -> Optional[PrefillPlan]:
        """Pop FCFS prompts into one padded batch. Always admits at least
        one request when a slot is free; beyond that the padded token total
        stays under max_prefill_tokens."""
        if not pending or num_free <= 0:
            return None
        take: List = []
        longest = 0
        while pending and len(take) < num_free:
            if len(np.asarray(pending[0].prompt).reshape(-1)) == 0:
                raise ValueError(
                    f"request {pending[0].rid}: empty prompt — a completion "
                    "conditioned on nothing would be silently garbage")
            cand = max(longest, len(pending[0].prompt))
            if take and self._bucket(cand) * (len(take) + 1) \
                    > self.max_prefill_tokens:
                break
            take.append(pending.popleft())
            longest = cand
        # prompts are NEVER truncated: the ring prefill paths handle
        # l > cache capacity exactly like the full-prompt reference (only
        # the last window+globals survive in the cache, as they should)
        l_pad = self._bucket(longest)
        tokens = np.zeros((len(take), l_pad), np.int32)
        lengths = np.zeros((len(take),), np.int32)
        for i, req in enumerate(take):
            p = np.asarray(req.prompt, np.int32).reshape(-1)
            tokens[i, :len(p)] = p
            lengths[i] = len(p)
        return PrefillPlan(requests=take, tokens=tokens, lengths=lengths)
