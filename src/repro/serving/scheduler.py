"""Admission scheduling for the continuous-batching engine.

The scheduler turns the pending FCFS queue into one padded, batched prefill
call: take as many waiting prompts as there are free slots, right-pad them
to a shared bucketed length, and stop early if the padded token count would
blow the prefill budget (the VMEM bound — prefill score memory scales with
padded tokens; the engine additionally chunks long batches along the
sequence axis). Bucketing pad lengths to `pad_to` multiples keeps the jit
cache small: the prefill function retraces per (rows, padded_len) pair only.

Under a device mesh the batch ROW count matters too: a prefill of n rows
only shards over the slot axis when n divides it, otherwise the whole
prefill silently replicates. `slot_quantum` (the engine passes the mesh's
slot-axis size) makes admission divisibility-aware: whenever more than one
quantum of prompts is available, the batch is trimmed to a quantum multiple
(the remainder stays queued for the next — also sharded — batch). A final
sub-quantum batch still admits, so nothing ever starves.
"""
from __future__ import annotations

import dataclasses
from typing import Deque, List, Optional, Tuple

import numpy as np


def normalize_prompt(prompt) -> np.ndarray:
    """Flatten any prompt spelling — list, list-of-lists, (L,), (1, L) —
    to the 1-D int32 the whole serving stack assumes. Measuring a (1, L)
    prompt with len() used to report 1 and mis-size the padded batch."""
    return np.asarray(prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class PrefillPlan:
    """One batched prefill: `tokens` (n, L_pad) right-padded int32 prompts
    for `requests`, with per-row real `lengths` (n,). `prefix_len` is the
    longest token prefix shared by EVERY row (radix-trie LCP, 0 for
    single-row plans) — a paged engine with prefix sharing enabled prefills
    those tokens once and block-shares the untouched prefix pages."""
    requests: List
    tokens: np.ndarray
    lengths: np.ndarray
    prefix_len: int = 0


class Scheduler:
    def __init__(self, *, max_prefill_tokens: int = 8192, pad_to: int = 16,
                 slot_quantum: int = 1, max_prompt_len: Optional[int] = None,
                 vocab_size: Optional[int] = None):
        """max_prompt_len / vocab_size: optional admission validation
        bounds. A request that violates one is REJECTED — popped off the
        queue into `take_rejected()` with a reason, never raised: one
        malformed request used to ValueError out of `plan` and kill the
        whole engine loop, losing every in-flight slot. max_prompt_len=None
        keeps long prompts admissible (the ring prefill serves them exactly
        — only the last window survives, as it should); set it when the
        deployment wants oversized prompts refused instead."""
        assert pad_to >= 1 and max_prefill_tokens >= pad_to
        assert slot_quantum >= 1
        self.max_prefill_tokens = max_prefill_tokens
        self.pad_to = pad_to
        self.slot_quantum = slot_quantum
        self.max_prompt_len = max_prompt_len
        self.vocab_size = vocab_size
        self._rejected: List[Tuple[object, str]] = []

    def _bucket(self, n: int) -> int:
        return -(-max(n, 1) // self.pad_to) * self.pad_to

    def _reject_reason(self, req) -> Optional[str]:
        """Why this request must not be admitted (None = admissible)."""
        try:
            head = normalize_prompt(req.prompt)
        except (ValueError, TypeError) as e:
            return f"malformed prompt: {e}"
        if head.size == 0:
            return ("empty prompt — a completion conditioned on nothing "
                    "would be silently garbage")
        if self.max_prompt_len is not None and head.size > self.max_prompt_len:
            return (f"prompt length {head.size} longer than "
                    f"max_prompt_len={self.max_prompt_len}")
        if self.vocab_size is not None and head.size:
            lo, hi = int(head.min()), int(head.max())
            if lo < 0 or hi >= self.vocab_size:
                return (f"token id out of range: [{lo}, {hi}] vs vocab "
                        f"size {self.vocab_size}")
        return None

    def take_rejected(self) -> List[Tuple[object, str]]:
        """Drain (request, reason) pairs rejected by `plan` since the last
        drain — the engine finalizes them as status='rejected' Results."""
        out, self._rejected = self._rejected, []
        return out

    def plan(self, pending: Deque, num_free: int) -> Optional[PrefillPlan]:
        """Pop FCFS prompts into one padded batch. Always admits at least
        one request when a slot is free; beyond that the padded token total
        stays under max_prefill_tokens and (when possible) the row count is
        a slot_quantum multiple so the prefill shards over the slot axis.
        Inadmissible requests (empty / oversized / out-of-vocab prompts)
        are popped into `take_rejected()` and never poison the batch."""
        if not pending or num_free <= 0:
            return None
        take: List = []
        flat: List[np.ndarray] = []
        longest = 0
        while pending and len(take) < num_free:
            reason = self._reject_reason(pending[0])
            if reason is not None:
                self._rejected.append((pending.popleft(), reason))
                continue
            head = normalize_prompt(pending[0].prompt)
            cand = max(longest, head.size)
            if take and self._bucket(cand) * (len(take) + 1) \
                    > self.max_prefill_tokens:
                break
            take.append(pending.popleft())
            flat.append(head)
            longest = cand
        if not take:          # everything pending was rejected
            return None
        q = self.slot_quantum
        if len(take) > q and len(take) % q:
            # return the sub-quantum tail to the queue head (FCFS intact):
            # a quantum-multiple batch shards; the tail rides the next batch
            keep = (len(take) // q) * q
            for req in reversed(take[keep:]):
                pending.appendleft(req)
            take, flat = take[:keep], flat[:keep]
            longest = max(p.size for p in flat)
        # prompts are NEVER truncated: the ring prefill paths handle
        # l > cache capacity exactly like the full-prompt reference (only
        # the last window+globals survive in the cache, as they should)
        l_pad = self._bucket(longest)
        tokens = np.zeros((len(take), l_pad), np.int32)
        lengths = np.zeros((len(take),), np.int32)
        for i, p in enumerate(flat):
            tokens[i, :p.size] = p
            lengths[i] = p.size
        from repro.serving.paged import batch_lcp
        return PrefillPlan(requests=take, tokens=tokens, lengths=lengths,
                           prefix_len=batch_lcp(flat))
