"""Device-resident continuous-batching serving engine.

The paper's FIFO K/V buffer is the serving-side win of window attention:
decode memory is O(window), not O(context) — SWAT's Fig. 3 linear-memory
claim. The engine turns that into throughput:

  * static batch of slots (TPU-friendly: shapes never change) with PER-SLOT
    ring write positions — every row of every cache tracks its own step, so
    slots at different depths share one batched kernel call,
  * batched, padded prefill: the scheduler packs all pending prompts that
    fit into one call (per-row `lengths` mask the padding), optionally
    chunked along the sequence axis so prefill VMEM is bounded by the chunk
    size rather than the longest prompt,
  * scan decode: N tokens per dispatch under `jax.lax.scan` with per-slot
    done/budget flags — the host syncs once per block instead of once per
    token (the seed engine's per-token round-trip),
  * per-slot temperature / top-k sampling (jitted; greedy rows take argmax).

Mesh-sharded serving (`ServingEngine(mesh=, profile=)`): every jitted entry
point carries `in_shardings`/`out_shardings` from the distributed/sharding.py
rule tables — params by `param_sharding` (placed ONCE at engine
construction), ring caches by `cache_sharding` (slot dim over ('pod','data'),
kv heads over 'model', per-slot `step` riding the slot axis), and the
per-slot decode state (`slot_last`/`slot_budget`/`slot_temp`/active flags)
by `decode_batch_sharding`. XLA then PARTITIONS decode across the mesh
instead of replicating it — the scan-decode block is the sync quantum. The
scheduler is told the slot-axis size so admitted prefill batches stay
divisible (and therefore sharded) whenever enough prompts are pending.

Determinism: the RNG key splits once per executed decode step and once per
prefill batch, in the same order whatever `scan_steps` is (blocks stop at
the earliest slot completion), so scan decode is token-for-token identical
to stepwise decode — the property test_serving.py pins down. The sharded
engine runs the same program partitioned, so it is token-for-token identical
to the single-device engine (tests/test_serving_sharded.py).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import time
import warnings
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Partitionable threefry keeps sharded sampling collective-free: legacy
# threefry (the pre-0.4.36 default) lowers cross-device permutes and a
# u32 all-reduce whenever an RNG output is sharded, which would plant
# collectives inside the slot-parallel decode scan. Trace-time flag, so
# flipping it here covers every program the engine compiles; it changes
# sampled (temps>0) token streams but never greedy decoding.
jax.config.update("jax_threefry_partitionable", True)

from repro.core import model as Mod
from repro.core.types import ModelConfig
from repro.serving import faults as F
from repro.serving import sampling
from repro.serving.drafter import NGramDrafter, get_drafter
from repro.serving.faults import FaultPlan
from repro.serving.scheduler import PrefillPlan, Scheduler, normalize_prompt
from repro.telemetry import events as TEV
from repro.telemetry import metrics as MX
from repro.telemetry.tracer import Tracer, prometheus_text

# the Result status taxonomy (see serving/README.md "Resilience"):
#   ok        full budget served (or prompt-only request)
#   rejected  never admitted: malformed/oversized prompt or queue overflow
#   poisoned  quarantined mid-decode: non-finite logits in the slot's row;
#             tokens holds everything emitted BEFORE the poison
#   deadline  per-request deadline expired (partial tokens kept)
#   failed    infrastructure failure after the slot's state was consumed
#             (e.g. kernel dispatch died after cache donation)
STATUSES = ("ok", "rejected", "poisoned", "deadline", "failed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # any int spelling; normalized to (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # seconds from run() submission; None = no deadline. Checked at block
    # boundaries (the host-sync quantum), so expiry resolution is one
    # decode block — an expired request finalizes with what it has.
    deadline: Optional[float] = None
    # infrastructure-failure retries: a request whose slot died with status
    # "failed" (cache donation consumed) is readmitted through the normal
    # queue up to this many times before it finalizes as failed. Retries
    # restart from the prompt — partial tokens from the dead slot are
    # discarded, never stitched.
    max_retries: int = 0

    def __post_init__(self):
        # normalize ONCE at the boundary: a (1, L) / list-of-lists prompt
        # used to len()-measure as 1 and crash (or mis-pad) at batch fill.
        # A ragged prompt that cannot normalize is kept as-is: the
        # scheduler rejects it per-request instead of raising here.
        try:
            self.prompt = normalize_prompt(self.prompt)
        except (ValueError, TypeError):
            pass


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]
    status: str = "ok"           # one of STATUSES
    reason: str = ""             # human-readable detail for status != ok
    retries: int = 0             # readmissions consumed (see max_retries)

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class _Compiled:
    """Jitted functions shared by every engine over the same
    (cfg, max_len, decode_impl, top_k, mesh, profile, tokens_per_step):
    compiles are per-model, engines are cheap per-session objects
    (constructing a second engine must not pay XLA again — `_get_compiled`
    memoizes these).

    tokens_per_step sizes the ring lookahead (caches get T-1 extra ring
    rows so a T-token decode step never evicts an in-window token) and is
    part of the compile identity: every cache shape, prefill, and scan
    program depends on it — the speculative-decode hook.

    With a mesh, every function is keyed by its batch-row count so each
    shape gets exact `in_shardings`/`out_shardings` (the sharding rules are
    divisibility-aware, so specs depend on the concrete row count).

    donate=True (the default) donates the ring-cache carry of every entry
    point that consumes one — the scan/spec-scan decode blocks, the
    cache-insert, and the chunked-prefill carry — so XLA aliases the caches
    in place instead of copying the full multi-MB buffer per call. The
    analyzer (repro.analysis) proves the aliases hold in the compiled
    executables; donate=False keeps the pre-donation programs around as the
    analyzer's known-bad fixture and for A/B benchmarking."""

    def __init__(self, cfg: ModelConfig, max_len: int, decode_impl: str,
                 top_k: int, mesh=None, profile: str = "tp",
                 tokens_per_step: int = 1, speculative: int = 0,
                 draft: Optional[NGramDrafter] = None, donate: bool = True,
                 faults: FaultPlan = FaultPlan(),
                 kv_layout: str = "contiguous", metrics: bool = False):
        self.cfg, self.max_len = cfg, max_len
        self.decode_impl, self.top_k = decode_impl, top_k
        # "paged": slot caches hold block pools + tables instead of
        # per-slot contiguous rings; decode gathers a ring VIEW per layer
        # (bitwise the contiguous kernel — PAGE_SIZE divides every
        # allocation), and admission/COW address blocks through tables
        self.kv_layout = kv_layout
        self.paged = kv_layout == "paged"
        self.tokens_per_step = tokens_per_step
        self.lookahead = tokens_per_step - 1
        self.speculative = speculative
        self.drafter = get_drafter(draft) if speculative else None
        self.donate = donate
        # frozen/hashable like the drafter spec: a plan with logit faults
        # compiles an extra countdown argument + one masked select into the
        # scan body; the default plan compiles the production program
        self.faults = faults
        # metrics=True compiles the device-counter carry (telemetry.metrics)
        # into the scan bodies — part of the compile identity like faults,
        # so metrics=False engines run today's exact programs. ring_mod is
        # the wrap modulus for the ring_wraps counter: static per engine.
        self.metrics = metrics
        self.ring_mod = MX.ring_modulus(cfg, max_len, tokens_per_step - 1)
        self.mesh, self.profile = mesh, profile
        if mesh is not None:
            from repro.distributed import sharding as Sh
            self._Sh = Sh
            pshapes = jax.eval_shape(
                lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
            self.param_sharding = Sh.param_sharding_serving(pshapes, mesh,
                                                            profile)
            self._rep = Sh.replicated(mesh)
        else:
            self._Sh = None
            self.param_sharding = None
            self._rep = None
        self._prefill_fns: Dict[int, Any] = {}
        self._chunk_fns: Dict[int, Any] = {}
        self._insert_fns: Dict[Tuple[int, int], Any] = {}
        self._sample_fns: Dict[int, Any] = {}
        self._scan_fns: Dict[Tuple[int, int], Any] = {}
        self._spec_fns: Dict[Tuple[int, int], Any] = {}
        self._init_fns: Dict[int, Any] = {}
        self._slot_init_fns: Dict[int, Any] = {}
        self._insert_paged_fns: Dict[Tuple[int, int], Any] = {}
        self._fixup_fns: Dict[Tuple[int, int], Any] = {}
        self._bcast_fns: Dict[int, Any] = {}

    # ------------------------------------------------------- sharding maps --
    def cache_sharding(self, n: int):
        shapes = jax.eval_shape(
            lambda: Mod.init_caches(self.cfg, n, self.max_len,
                                    lookahead=self.lookahead))
        return self._Sh.cache_sharding(shapes, self.mesh)

    def slot_cache_sharding(self, slots: int):
        """Sharding of the engine's SLOT caches — the decode-scan carry.
        Contiguous engines: same as cache_sharding. Paged engines: the pool
        leaves (pk/pv/table) carry their own rules — under a mesh the pool
        is per-slot (local block ids), slot dim over the batch axes, so
        slot-parallel paged decode stays collective-free."""
        if not self.paged:
            return self.cache_sharding(slots)
        shapes = jax.eval_shape(
            lambda: Mod.init_paged_caches(self.cfg, slots, self.max_len,
                                          lookahead=self.lookahead,
                                          shared_pool=self.mesh is None))
        return self._Sh.cache_sharding(shapes, self.mesh)

    def batch_sharding(self, shapes, n: int, slot_dim: int = 0):
        """decode_batch_sharding over a pytree of ShapeDtypeStructs."""
        return self._Sh.decode_batch_sharding(shapes, self.mesh, n,
                                              slot_dim=slot_dim)

    def _sds(self, shape, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(shape, dtype)

    def slot_vector_shardings(self, slots: int) -> Dict[str, Any]:
        """Placement of the staged per-slot decode vectors (engine._dev).
        Must match the scan/spec_scan in_shardings exactly: the block
        dispatch runs under transfer_guard("disallow"), so a host-staged
        vector left on the default device would need an implicit
        (disallowed) reshard onto the mesh."""
        veci = self.batch_sharding(self._sds((slots,)), slots)
        vecb = self.batch_sharding(self._sds((slots,), jnp.bool_), slots)
        sh = {"tok": veci, "budget": veci, "active": vecb,
              "poisoned": vecb,
              "temps": self.batch_sharding(
                  self._sds((slots,), jnp.float32), slots),
              "anyt": self._rep}
        if self.faults.has_logit_faults:
            sh["fin"] = veci
        if self.drafter is not None:
            sh["hist"] = self.batch_sharding(
                self._sds((slots, self.drafter.history)), slots)
            sh["hcnt"] = veci
        return sh

    def _donate(self, *argnums: int) -> Tuple[int, ...]:
        """Carry argnums to donate (empty when donation is disabled)."""
        return tuple(argnums) if self.donate else ()

    def slot_quantum(self, slots: int) -> int:
        """Slot-axis size when the engine's slot count shards over it —
        the scheduler keeps prefill batches divisible by this."""
        if self.mesh is None:
            return 1
        size = 1
        for a in ("pod", "data"):
            if a in self.mesh.axis_names:
                size *= self.mesh.shape[a]
        return size if size > 1 and slots % size == 0 else 1

    def _act_sharding(self, n: int, t: int = 1):
        if self.mesh is None:
            return None
        return self.batch_sharding(
            self._sds((n, t, self.cfg.d_model), jnp.float32), n)

    # ------------------------------------------------------------ prefill --
    def prefill(self, n: int):
        if n not in self._prefill_fns:
            act = self._act_sharding(n)

            def fn(p, tok, lens):
                return Mod.prefill(p, self.cfg, {"tokens": tok},
                                   max_len=self.max_len, lengths=lens,
                                   act_sharding=act,
                                   lookahead=self.lookahead)
            if self.mesh is None:
                self._prefill_fns[n] = jax.jit(fn)
            else:
                vec = self.batch_sharding(self._sds((n,)), n)
                tok_sh = self.batch_sharding(self._sds((n, 1)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, 1, self.cfg.vocab_size), jnp.float32), n)
                self._prefill_fns[n] = jax.jit(
                    fn,
                    in_shardings=(self.param_sharding, tok_sh, vec),
                    out_shardings=(logit_sh, self.cache_sharding(n)))
        return self._prefill_fns[n]

    def chunk(self, n: int):
        if n not in self._chunk_fns:
            act = self._act_sharding(n)
            fn = functools.partial(self._chunk_impl, act_sharding=act)
            # the chunk loop carries (caches, last_logits): donate both so
            # walking a long prompt re-uses one cache allocation instead of
            # copying it per chunk
            don = self._donate(1, 5)
            if self.mesh is None:
                self._chunk_fns[n] = jax.jit(fn, donate_argnums=don)
            else:
                vec = self.batch_sharding(self._sds((n,)), n)
                tok_sh = self.batch_sharding(self._sds((n, 1)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, self.cfg.vocab_size), jnp.float32), n)
                cache_sh = self.cache_sharding(n)
                self._chunk_fns[n] = jax.jit(
                    fn,
                    in_shardings=(self.param_sharding, cache_sh, tok_sh,
                                  self._rep, vec, logit_sh),
                    out_shardings=(logit_sh, cache_sh),
                    donate_argnums=don)
        return self._chunk_fns[n]

    def _chunk_impl(self, params, caches, tok, pos0, lengths, last_logits,
                    act_sharding=None):
        """One prefill chunk + carry of each row's last-real-token logits
        (pos0 is traced: one compile serves every chunk index). Only the
        gathered (B, 1, D) row is unembedded — never the whole chunk."""
        x, caches = Mod.prefill_chunk(
            params, self.cfg, {"tokens": tok}, caches, pos0, lengths,
            act_sharding=act_sharding, lookahead=self.lookahead)
        t = tok.shape[1]
        tpos = lengths - 1 - pos0
        hit = (tpos >= 0) & (tpos < t)
        xsel = jnp.take_along_axis(
            x, jnp.broadcast_to(
                jnp.clip(tpos, 0, t - 1)[:, None, None],
                (x.shape[0], 1, x.shape[2])), axis=1)
        sel = Mod._unembed(params, self.cfg, xsel)[:, 0]
        return jnp.where(hit[:, None], sel, last_logits), caches

    def insert(self, slots: int, n: int):
        key = (slots, n)
        if key not in self._insert_fns:
            def fn(full, one, idx):
                return jax.tree.map(
                    lambda f, o: f.at[:, idx].set(o.astype(f.dtype)),
                    full, one)
            # donate the full slot caches: admission scatters n fresh rows
            # into them, everything else is carried through unchanged — an
            # un-donated insert copies every cache at every admission
            don = self._donate(0)
            if self.mesh is None:
                self._insert_fns[key] = jax.jit(fn, donate_argnums=don)
            else:
                self._insert_fns[key] = jax.jit(
                    fn,
                    in_shardings=(self.cache_sharding(slots),
                                  self.cache_sharding(n), self._rep),
                    out_shardings=self.cache_sharding(slots),
                    donate_argnums=don)
        return self._insert_fns[key]

    def sample(self, n: int):
        if n not in self._sample_fns:
            fn = functools.partial(sampling.sample, top_k=self.top_k)
            if self.mesh is None:
                self._sample_fns[n] = jax.jit(fn)
            else:
                veci = self.batch_sharding(self._sds((n,)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, self.cfg.vocab_size), jnp.float32), n)
                # temps rides REPLICATED (16 bytes): sampling's all-greedy
                # fast path does `jnp.any(temps > 0)`, which on a slot-
                # sharded vector lowers to a pred[] all-reduce — the only
                # collective left on the slot-parallel hot path. Replicated
                # it folds to a local reduce.
                self._sample_fns[n] = jax.jit(
                    fn, in_shardings=(self._rep, logit_sh, self._rep),
                    out_shardings=veci)
        return self._sample_fns[n]

    def fresh_caches(self, n: int):
        if n not in self._init_fns:
            out_sh = None if self.mesh is None else self.cache_sharding(n)
            self._init_fns[n] = jax.jit(
                lambda: Mod.init_caches(self.cfg, n, self.max_len,
                                        lookahead=self.lookahead),
                out_shardings=out_sh)
        return self._init_fns[n]()

    # -------------------------------------------------------------- paged --
    def fresh_slot_caches(self, slots: int):
        """The engine's slot caches: paged pools+tables for kv_layout=
        'paged', plain contiguous rings otherwise. Prefill always runs
        contiguous — rows paginate at insert."""
        if not self.paged:
            return self.fresh_caches(slots)
        if slots not in self._slot_init_fns:
            out_sh = (None if self.mesh is None
                      else self.slot_cache_sharding(slots))
            self._slot_init_fns[slots] = jax.jit(
                lambda: Mod.init_paged_caches(
                    self.cfg, slots, self.max_len, lookahead=self.lookahead,
                    shared_pool=self.mesh is None),
                out_shardings=out_sh)
        return self._slot_init_fns[slots]()

    def insert_paged(self, slots: int, n: int):
        """Paged admission: reshape n freshly prefilled CONTIGUOUS rows
        into page blocks, scatter them to each row's table blocks, and push
        the full host table mirror atomically in the same dispatch (the
        staleness contract in serving/paged.py). Shared-prefix admissions
        point several rows at the same block ids — the duplicate scatters
        carry bitwise-identical content by the shareable-block invariant,
        so whichever lands is exact."""
        key = (slots, n)
        if key not in self._insert_paged_fns:
            def fn(full, one, idx, tables):
                out = {}
                for li, fc in full.items():
                    oc = one[li]
                    if not (isinstance(fc, dict) and "pk" in fc):
                        out[li] = jax.tree.map(
                            lambda f, o: f.at[:, idx].set(o.astype(f.dtype)),
                            fc, oc)
                        continue
                    nb = fc["table"].shape[-1]
                    page = fc["pk"].shape[-2]
                    tbl = tables[li]
                    nc = dict(fc)
                    sb, nn, hh, cap, dd = oc["k"].shape

                    def blocks(a):
                        return a.reshape(sb, nn, hh, nb, page, dd
                                         ).transpose(0, 1, 3, 2, 4, 5)
                    if fc["pk"].ndim == 5:       # shared global-id pool
                        dest = tbl[idx].reshape(-1)
                        for pkey, ckey in (("pk", "k"), ("pv", "v")):
                            blk = blocks(oc[ckey]).reshape(
                                sb, nn * nb, hh, page, dd)
                            nc[pkey] = fc[pkey].at[:, dest].set(
                                blk.astype(fc[pkey].dtype))
                    else:                         # per-slot local-id pool
                        for pkey, ckey in (("pk", "k"), ("pv", "v")):
                            nc[pkey] = fc[pkey].at[:, idx, :nb].set(
                                blocks(oc[ckey]).astype(fc[pkey].dtype))
                    nc["table"] = jnp.broadcast_to(
                        tbl[None].astype(fc["table"].dtype),
                        fc["table"].shape)
                    nc["step"] = fc["step"].at[:, idx].set(
                        oc["step"].astype(fc["step"].dtype))
                    for extra in ("xk", "xv"):
                        if extra in fc:
                            nc[extra] = fc[extra].at[:, idx].set(
                                oc[extra].astype(fc[extra].dtype))
                    out[li] = nc
                return out
            don = self._donate(0)
            if self.mesh is None:
                self._insert_paged_fns[key] = jax.jit(fn, donate_argnums=don)
            else:
                self._insert_paged_fns[key] = jax.jit(
                    fn,
                    in_shardings=(self.slot_cache_sharding(slots),
                                  self.cache_sharding(n), self._rep,
                                  self._rep),
                    out_shardings=self.slot_cache_sharding(slots),
                    donate_argnums=don)
        return self._insert_paged_fns[key]

    def fixup(self, slots: int, m: int):
        """Pre-block paged maintenance: copy-on-write block moves (m (src,
        dst) pairs per layer, padded with scratch self-moves) plus a
        wholesale push of the host table mirror. dst ids are freshly
        allocated, so the gather of the OLD pool before the scatter is
        consistent — no move ever reads another move's destination."""
        key = (slots, m)
        if key not in self._fixup_fns:
            def fn(caches, tables, srcs, dsts):
                out = {}
                for li, c in caches.items():
                    if not (isinstance(c, dict) and "pk" in c):
                        out[li] = c
                        continue
                    nc = dict(c)
                    if m:
                        s_, d_ = srcs[li], dsts[li]
                        nc["pk"] = nc["pk"].at[:, d_].set(nc["pk"][:, s_])
                        nc["pv"] = nc["pv"].at[:, d_].set(nc["pv"][:, s_])
                    nc["table"] = jnp.broadcast_to(
                        tables[li][None].astype(c["table"].dtype),
                        c["table"].shape)
                    out[li] = nc
                return out
            don = self._donate(0)
            if self.mesh is None:
                self._fixup_fns[key] = jax.jit(fn, donate_argnums=don)
            else:
                sh = self.slot_cache_sharding(slots)
                self._fixup_fns[key] = jax.jit(
                    fn, in_shardings=(sh, self._rep, self._rep, self._rep),
                    out_shardings=sh, donate_argnums=don)
        return self._fixup_fns[key]

    def broadcast_prefix(self, n: int):
        """Prefix-sharing prefill: replicate a 1-row prefix cache (and its
        last-token logits) across n batch rows. jnp.repeat copies rows, so
        every row starts bitwise the single-row prefill."""
        if n not in self._bcast_fns:
            def fn(c1, lg):
                caches = jax.tree.map(lambda x: jnp.repeat(x, n, axis=1), c1)
                return jnp.broadcast_to(lg, (n,) + lg.shape[1:]), caches
            if self.mesh is None:
                self._bcast_fns[n] = jax.jit(fn)
            else:
                logit_sh = self.batch_sharding(
                    self._sds((n, self.cfg.vocab_size), jnp.float32), n)
                self._bcast_fns[n] = jax.jit(
                    fn, out_shardings=(logit_sh, self.cache_sharding(n)))
        return self._bcast_fns[n]

    # ------------------------------------------------------------- decode --
    def scan(self, n: int, slots: int):
        key = (n, slots)
        if key not in self._scan_fns:
            self._scan_fns[key] = self._make_scan(n, slots)
        return self._scan_fns[key]

    def _make_scan(self, n: int, slots: int):
        cfg, impl, top_k = self.cfg, self.decode_impl, self.top_k
        lookahead = self.lookahead
        act = self._act_sharding(slots)
        inject = self.faults.has_logit_faults
        metrics, ring_mod = self.metrics, self.ring_mod
        # poison value per slot: a NUMPY constant baked into the trace
        # (eager jnp here would dispatch under the engine's transfer guard)
        bad_val = (np.where(self.faults.inf_mask(slots),
                            np.inf, np.nan).astype(np.float32)
                   if inject else None)

        def run_scan(params, caches, tok, active, budget, temps, anyt, key,
                     poisoned, fin, mx):
            def body(carry, _):
                caches, tok, active, budget, key, poisoned, fin, mx = carry
                logits, caches = Mod.decode_step(
                    params, cfg, {"tokens": tok[:, None]}, caches, impl=impl,
                    act_sharding=act, lookahead=lookahead)
                lg = logits[:, 0]
                if inject:
                    # chaos countdown: when a slot's trigger step arrives,
                    # its whole logits row becomes nan/inf — one masked
                    # select, invisible to every other row
                    lg = jnp.where((active & (fin == 0))[:, None],
                                   bad_val[:, None], lg)
                key, sub = jax.random.split(key)
                nxt = sampling.sample(sub, lg, temps, top_k,
                                      any_sampling=anyt)
                # numerical guard: a non-finite row is QUARANTINED — not
                # emitted, budget untouched, slot deactivated for the host
                # to finalize as status "poisoned". Every op here is
                # row-wise and the RNG split count is unchanged, so on a
                # clean run (bad == False) the program's healthy-slot
                # tokens are bitwise the unguarded engine's.
                bad = active & ~sampling.finite_rows(lg)
                ok = active & ~bad
                nxt = jnp.where(ok, nxt, tok)
                emitted = ok
                budget = budget - ok.astype(jnp.int32)
                poisoned = poisoned | bad
                active = ok & (budget > 0)
                if inject:
                    fin = fin - ok.astype(jnp.int32)
                if metrics:
                    # device counters: pure per-slot int32 adds over values
                    # the body already holds — sampling/RNG/guard math are
                    # untouched, so tokens stay bitwise metrics-off
                    mx = MX.seq_update(mx, ok, bad, ring_mod)
                return ((caches, nxt, active, budget, key, poisoned, fin,
                         mx), (nxt, emitted))

            carry, (toks, emit) = jax.lax.scan(
                body, (caches, tok, active, budget, key, poisoned, fin, mx),
                None, length=n)
            caches, tok, active, budget, key, poisoned, fin, mx = carry
            return (caches, tok, active, budget, key, toks, emit, poisoned
                    ) + ((fin,) if inject else ()) + ((mx,) if metrics
                                                      else ())

        # fin / mx ride the carry as empty pytrees (None) when their
        # feature is off, so the plain program has no extra state at all
        if inject and metrics:
            fn = run_scan
        elif inject:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   poisoned, fin):
                return run_scan(params, caches, tok, active, budget, temps,
                                anyt, key, poisoned, fin, None)
        elif metrics:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   poisoned, mx):
                return run_scan(params, caches, tok, active, budget, temps,
                                anyt, key, poisoned, None, mx)
        else:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   poisoned):
                return run_scan(params, caches, tok, active, budget, temps,
                                anyt, key, poisoned, None, None)

        # donate the ring caches: the decode block's only multi-MB carry.
        # Un-donated, XLA materializes a full copy of every K/V ring per
        # block (the analyzer's first real catch); donated, the compiled
        # executable aliases them input->output and the scan mutates the
        # same buffers the engine re-feeds next block.
        # the metrics carry is donated like the caches: tiny, but donation
        # keeps the counters a true in-place accumulator (no copy per block
        # and the telemetry lint can prove the alias)
        mx_arg = 9 + (1 if inject else 0)
        don = self._donate(1, mx_arg) if metrics else self._donate(1)
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=don)
        cache_sh = self.slot_cache_sharding(slots)
        veci = self.batch_sharding(self._sds((slots,)), slots)
        vecb = self.batch_sharding(self._sds((slots,), jnp.bool_), slots)
        vecf = self.batch_sharding(self._sds((slots,), jnp.float32), slots)
        blk = self.batch_sharding(self._sds((n, slots)), slots, slot_dim=1)
        fin_in = (veci,) if inject else ()
        mx_in = ((MX.metrics_shardings(veci, self._rep),) if metrics
                 else ())
        return jax.jit(
            fn,
            in_shardings=(self.param_sharding, cache_sh, veci, vecb, veci,
                          vecf, self._rep, self._rep, vecb) + fin_in + mx_in,
            out_shardings=(cache_sh, veci, vecb, veci, self._rep, blk, blk,
                           vecb) + fin_in + mx_in,
            donate_argnums=don)

    # ------------------------------------------------------- speculative --
    def spec_scan(self, n: int, slots: int):
        key = (n, slots)
        if key not in self._spec_fns:
            self._spec_fns[key] = self._make_spec_scan(n, slots)
        return self._spec_fns[key]

    def _make_spec_scan(self, n: int, slots: int):
        """n draft/verify/accept steps per dispatch. Each step feeds the
        model (B, T=k+1) tokens — the slot's pending token plus k drafts —
        in ONE `decode_step` (the PR-3 lookahead-ring primitive), then:

          accept   logits[:, j] is the model's next-token distribution
                   given x[:, :j+1], so draft x[:, j+1] is kept iff it
                   equals the model's own choice ver[:, j]; `acc` is the
                   longest all-match prefix and the step emits e = acc+1
                   tokens — acc verified drafts plus the model's bonus
                   token after them. Every emitted token is the model's
                   output for a fully verified prefix, hence greedy spec
                   decode is bitwise the sequential engine.
          rollback `decode_step` advanced every ring `step` by T and wrote
                   T rows; setting step -= T - e keeps exactly the rows a
                   sequential engine would hold after e tokens. The T-e
                   rejected rows are garbage but DEAD: the lookahead rows
                   mean no in-window token was evicted, the stale slots
                   reconstruct (ring_slot_positions) to positions the
                   window/validity mask drops, and the very next step's
                   T-row insert starts at step and overwrites all of them
                   before any attention read. Inactive slots take e=0, so
                   their step is restored exactly (no drift).
          budget   e is clamped per slot to the remaining budget, so a
                   slot never overshoots mid-block; done slots go
                   inactive and the loop exits early when none remain.

        A `lax.while_loop` (not scan) so the RNG key splits once per
        EXECUTED step — the same determinism contract as the sequential
        scan. Verify positions sample under fold_in(sub, j); greedy rows
        ignore the key entirely, which is why the identity guarantee is
        greedy-only (sampled rows are distributionally exact — each token
        is drawn conditioned on a verified prefix — but ride a different
        key stream than sequential decode)."""
        cfg, impl, top_k = self.cfg, self.decode_impl, self.top_k
        k = self.speculative
        t = k + 1
        assert self.lookahead >= k, (self.lookahead, k)
        drafter = self.drafter
        act = self._act_sharding(slots, t)
        inject = self.faults.has_logit_faults
        metrics, ring_mod = self.metrics, self.ring_mod
        bad_val = (np.where(self.faults.inf_mask(slots),
                            np.inf, np.nan).astype(np.float32)
                   if inject else None)
        corrupt = (self.faults.draft_mask(slots)
                   if self.faults.corrupt_draft_slots else None)

        def run_spec(params, caches, tok, active, budget, temps, anyt, key,
                     hist, hcnt, poisoned, fin, mx):
            toks0 = jnp.zeros((n, slots, t), jnp.int32)
            emit0 = jnp.zeros((n, slots, t), jnp.bool_)
            active0 = active

            def cond(carry):
                i, _, _, active, *_ = carry
                # exit as soon as ANY slot drains (not just all): a spec
                # slot's finish step is data-dependent (acceptance), so
                # running the block to n strands the freed slot idle
                # until the block boundary — exiting returns control to
                # the scheduler, which refills and redispatches. The
                # sequential scan never needs this: its block length
                # min(budgets) already ends exactly at first retirement.
                # Quarantined slots flip active too, so poison exits here.
                return (i < n) & jnp.all(active == active0)

            def body(carry):
                (i, caches, tok, active, budget, key, hist, hcnt, poisoned,
                 fin, toks_buf, emit_buf, mx) = carry
                drafts = drafter.propose(hist, hcnt, k)
                if corrupt is not None:
                    # chaos: replace the slot's proposals with out-of-vocab
                    # garbage — sanitize below must keep it harmless
                    drafts = jnp.where(corrupt[:, None],
                                       jnp.int32(cfg.vocab_size + 1337),
                                       drafts)
                # proposals are suggestions, never trusted: clip into the
                # vocab so a corrupt drafter can't exploit OOB-gather
                # clamping (garbage fails verification instead)
                drafts = drafter.sanitize(drafts, cfg.vocab_size)
                x = jnp.concatenate([tok[:, None], drafts], axis=1)
                logits, caches = Mod.decode_step(
                    params, cfg, {"tokens": x}, caches, impl=impl,
                    act_sharding=act, lookahead=k)
                if inject:
                    # a spec step verifies a window of T positions; poison
                    # exactly the position the countdown lands on (window
                    # position fin = token index tokens_done + fin), so a
                    # poisoned request keeps exactly target_idx tokens on
                    # every engine flavor — sequential and speculative agree
                    pos = jnp.arange(t, dtype=jnp.int32)[None, :]
                    hitpos = ((active & (fin >= 0) & (fin < t))[:, None]
                              & (pos == fin[:, None]))
                    logits = jnp.where(hitpos[:, :, None],
                                       bad_val[:, None, None], logits)
                key, sub = jax.random.split(key)
                # one batched sample over the T verify positions (vmap is
                # bitwise the per-j loop: same fold_in(sub, j) keys, same
                # row math) — unrolling T sample chains costs as much as
                # the whole forward on small models
                subs = jax.vmap(
                    lambda j: jax.random.fold_in(sub, j))(jnp.arange(t))
                ver = jax.vmap(
                    lambda kj, lj: sampling.sample(kj, lj, temps, top_k,
                                                   any_sampling=anyt),
                    in_axes=(0, 1), out_axes=1)(subs, logits)  # (B, T)
                # numerical guard over every verify position. A slot is
                # quarantined only when a non-finite position would actually
                # be CONSUMED (its index < the acceptance-gated emission
                # count): it emits the verified-clean prefix strictly before
                # the first bad position, then deactivates for host
                # quarantine. A bad position beyond acceptance was never
                # going to be emitted — the slot stays live and the guard
                # re-checks next step. Row/position-wise only, so healthy
                # slots are bitwise the unguarded program.
                finpos = jnp.all(jnp.isfinite(logits), axis=-1)   # (B, T)
                first_bad = jnp.where(
                    jnp.all(finpos, axis=1), jnp.int32(t),
                    jnp.argmin(finpos.astype(jnp.int32), axis=1)
                    .astype(jnp.int32))
                match = (drafts == ver[:, :k]).astype(jnp.int32)
                acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                e_clean = jnp.minimum(acc + 1, budget)
                bad = active & (first_bad < e_clean)
                ok = active & ~bad
                e = jnp.where(active,
                              jnp.where(bad, first_bad, e_clean),
                              0)
                caches = jax.tree.map(
                    lambda c: ({**c, "step": c["step"] - t
                                + e[None, :].astype(c["step"].dtype)}
                               if isinstance(c, dict) and "step" in c else c),
                    caches, is_leaf=lambda c: isinstance(c, dict)
                    and "step" in c)
                newlast = jnp.take_along_axis(
                    ver, jnp.maximum(e - 1, 0)[:, None], axis=1)[:, 0]
                tok = jnp.where(ok, newlast, tok)
                hist, hcnt = drafter.observe(hist, hcnt, ver, e)
                emitted = jnp.arange(t, dtype=jnp.int32)[None, :] < e[:, None]
                budget = budget - e
                poisoned = poisoned | bad
                active = ok & (budget > 0)
                if inject:
                    fin = fin - e
                if metrics:
                    # mirrors the host-side spec accounting exactly: a slot
                    # that ran (e >= 1) proposed k drafts, kept e - 1
                    mx = MX.spec_update(mx, e, bad, k, ring_mod)
                return (i + 1, caches, tok, active, budget, key, hist, hcnt,
                        poisoned, fin,
                        toks_buf.at[i].set(ver), emit_buf.at[i].set(emitted),
                        mx)

            (steps, caches, tok, active, budget, key, hist, hcnt, poisoned,
             fin, toks, emit, mx) = jax.lax.while_loop(
                cond, body, (jnp.int32(0), caches, tok, active, budget, key,
                             hist, hcnt, poisoned, fin, toks0, emit0, mx))
            return (caches, tok, active, budget, key, hist, hcnt, toks,
                    emit, steps, poisoned) + ((fin,) if inject else ()
                                              ) + ((mx,) if metrics else ())

        # fin / mx: empty (None) carries when their feature is off
        if inject and metrics:
            fn = run_spec
        elif inject:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   hist, hcnt, poisoned, fin):
                return run_spec(params, caches, tok, active, budget, temps,
                                anyt, key, hist, hcnt, poisoned, fin, None)
        elif metrics:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   hist, hcnt, poisoned, mx):
                return run_spec(params, caches, tok, active, budget, temps,
                                anyt, key, hist, hcnt, poisoned, None, mx)
        else:
            def fn(params, caches, tok, active, budget, temps, anyt, key,
                   hist, hcnt, poisoned):
                return run_spec(params, caches, tok, active, budget, temps,
                                anyt, key, hist, hcnt, poisoned, None, None)

        # caches + metrics carries donated: see _make_scan
        mx_arg = 11 + (1 if inject else 0)
        don = self._donate(1, mx_arg) if metrics else self._donate(1)
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=don)
        cache_sh = self.slot_cache_sharding(slots)
        veci = self.batch_sharding(self._sds((slots,)), slots)
        vecb = self.batch_sharding(self._sds((slots,), jnp.bool_), slots)
        vecf = self.batch_sharding(self._sds((slots,), jnp.float32), slots)
        hist_sh = self.batch_sharding(
            self._sds((slots, drafter.history)), slots)
        blk = self.batch_sharding(
            self._sds((n, slots, t)), slots, slot_dim=1)
        fin_in = (veci,) if inject else ()
        mx_in = ((MX.metrics_shardings(veci, self._rep),) if metrics
                 else ())
        return jax.jit(
            fn,
            in_shardings=(self.param_sharding, cache_sh, veci, vecb, veci,
                          vecf, self._rep, self._rep, hist_sh, veci,
                          vecb) + fin_in + mx_in,
            out_shardings=(cache_sh, veci, vecb, veci, self._rep, hist_sh,
                           veci, blk, blk, self._rep, vecb) + fin_in + mx_in,
            donate_argnums=don)


@functools.lru_cache(maxsize=16)
def _get_compiled(cfg: ModelConfig, max_len: int, decode_impl: str,
                  top_k: int, mesh=None, profile: str = "tp",
                  tokens_per_step: int = 1, speculative: int = 0,
                  draft: Optional[NGramDrafter] = None,
                  donate: bool = True,
                  faults: FaultPlan = FaultPlan(),
                  kv_layout: str = "contiguous",
                  metrics: bool = False) -> _Compiled:
    return _Compiled(cfg, max_len, decode_impl, top_k, mesh, profile,
                     tokens_per_step, speculative, draft, donate, faults,
                     kv_layout, metrics)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 8,
                 max_len: int = 4096, seed: int = 0, scan_steps: int = 8,
                 batch_prefill: bool = True, prefill_chunk: int = 0,
                 max_prefill_tokens: int = 8192, pad_to: int = 16,
                 top_k: int = 0, decode_impl: str = "ref",
                 mesh=None, profile: str = "tp", tokens_per_step: int = 1,
                 speculative: int = 0, draft: Optional[NGramDrafter] = None,
                 donate: bool = True, transfer_guard: bool = True,
                 faults: Optional[FaultPlan] = None,
                 max_prompt_len: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 spec_min_acceptance: float = 0.0,
                 spec_acceptance_window: int = 4,
                 spec_retry_blocks: int = 8,
                 spec_resume_acceptance: Optional[float] = None,
                 kv_layout: str = "contiguous",
                 share_prefix: bool = False,
                 share_min_prefix: int = 16,
                 metrics: bool = False,
                 trace_capacity: int = 4096):
        """scan_steps=1 degenerates to the seed engine's per-token host
        sync; prefill_chunk=0 disables sequence-axis chunking (single-shot
        batched prefill); batch_prefill=False admits one prompt per prefill
        call (the seed behavior, kept for benchmarking).

        tokens_per_step: ring lookahead for multi-token decode steps — the
        caches carry T-1 extra ring rows and every compiled entry point is
        keyed by it, so a speculative-decode step can verify T draft
        tokens per dispatch on these caches. Generated tokens are unchanged
        (the positional window mask hides the extra ring depth).

        speculative: draft tokens per decode step (0 = sequential decode).
        Each step proposes `speculative` tokens with `draft` (default:
        NGramDrafter self-drafting), verifies them all in one decode_step
        dispatch, and emits the longest verified prefix plus the model's
        own next token — 1..speculative+1 tokens per step per slot. Greedy
        requests produce bitwise the sequential engine's tokens (the
        tests/test_speculative.py contract); acceptance telemetry
        accumulates in `self.stats` / `self.acceptance_rate`. Forces
        tokens_per_step up to speculative+1 so the ring carries the
        lookahead rows the rollback guarantee needs.

        mesh: optional jax.sharding.Mesh — params are placed once at
        construction (`param_sharding(profile)`), caches/decode state carry
        the serving sharding rules, and every jitted call runs partitioned.
        batch_slots should be a multiple of the slot-axis size
        (('pod',)'data') for the slot dim to actually shard; indivisible
        counts degrade gracefully to replication.

        donate: donate the ring-cache carries of the decode-scan,
        cache-insert, and chunked-prefill entry points so the compiled
        executables alias them in place (no full-cache copy per block —
        tokens are unchanged, only buffer reuse). False keeps the copying
        programs: the analyzer's known-bad fixture and the serve_bench
        donation A/B.

        transfer_guard: run the steady-state decode dispatch under
        jax.transfer_guard("disallow") so any implicit host<->device
        transfer that sneaks into the hot loop raises instead of silently
        syncing every block (the scheduled host syncs — staging admitted
        slots, draining block outputs — are explicit transfers and stay
        legal).

        faults: a `serving.faults.FaultPlan` — deterministic chaos layer.
        Part of the compile identity: logit faults compile a countdown
        vector + one masked select into the scan body; the default plan
        compiles the production program. The numerical GUARDS (quarantine
        of non-finite rows) are always compiled in — on a clean run they
        are bitwise-invisible.

        max_prompt_len: reject (status "rejected") prompts longer than
        this instead of admitting them; None (default) serves long prompts
        via the ring exactly as before.
        max_pending: bounded-queue backpressure — `run()` rejects requests
        beyond this queue depth (status "rejected", reason "queue
        overflow") instead of buffering unboundedly under overload.

        spec_min_acceptance: speculative-decode auto-disable — when the
        windowed draft acceptance rate (over `spec_acceptance_window`
        spec blocks) drops below this, the engine decodes sequentially
        (same tokens for greedy requests, no wasted verify lanes). After
        `spec_retry_blocks` sequential blocks it probes with one spec
        block and re-enables only if that block's acceptance reaches
        `spec_resume_acceptance` (default: same threshold) — the
        hysteresis that stops flapping. 0.0 (default) disables the
        ladder.

        kv_layout: "contiguous" (per-slot ring buffers, the historical
        layout) or "paged" — slot caches become fixed-size PAGE_SIZE-row
        blocks in a device pool addressed through per-slot block tables
        (serving/paged.py). Every allocation tiles exactly into pages, so
        the decode gather-view is bitwise the contiguous ring and tokens
        are IDENTICAL across layouts; what pages buy is block-granular
        bookkeeping — prefix sharing, copy-on-write, O(1) slot free.
        share_prefix: paged single-device engines only — when an admitted
        batch shares a token prefix of at least `share_min_prefix`
        (PrefillPlan.prefix_len, the scheduler's radix-trie LCP) and
        prefill chunking is on, the prefix prefills ONCE, broadcasts to
        every row, and untouched prefix blocks are refcount-shared until
        a ring write diverges them (copy-on-write).

        metrics: compile device-resident telemetry counters (swatscope
        layer 1) into the decode/verify scan bodies — one extra donated
        int32-pytree carry, read out ONLY at `device_metrics()` /
        `metrics_text()`, never inside a block. Part of the compile
        identity: metrics=False (default) engines run today's exact
        programs; metrics=True tokens are bitwise identical (the
        test_telemetry.py contract).
        trace_capacity: ring-buffer depth of the always-on host-side
        `self.tracer` (request lifecycle spans, decode-block spans, and
        the unified degradation-event stream). Bounded memory forever —
        O(trace_capacity), however long the engine serves."""
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.scan_steps = max(1, scan_steps)
        self.batch_prefill = batch_prefill
        self.prefill_chunk = (prefill_chunk
                              if Mod.prefill_chunkable(cfg) else 0)
        self.top_k = top_k
        self.decode_impl = decode_impl
        self.speculative = max(0, speculative)
        if self.speculative:
            assert Mod.speculative_supported(cfg), (
                "speculative decode needs rotary positions and "
                "attention-only layers (no mamba/encoder-decoder state to "
                "roll back); config %s does not qualify" % (cfg.name,))
        self.tokens_per_step = max(1, tokens_per_step, self.speculative + 1)
        self.mesh, self.profile = mesh, profile
        self.transfer_guard = transfer_guard
        self.faults = faults if faults is not None else FaultPlan()
        if self.faults.fail_pallas_dispatch:
            F.install_kernel_failure()
        assert kv_layout in ("contiguous", "paged"), kv_layout
        self.kv_layout = kv_layout
        # block sharing needs the shared global-id pool (single device);
        # sharded engines keep per-slot local ids, so sharing is a no-op
        self.share_prefix = (bool(share_prefix) and kv_layout == "paged"
                             and mesh is None)
        self.share_min_prefix = max(1, share_min_prefix)
        self.metrics = bool(metrics)
        self.key = jax.random.PRNGKey(seed)
        self._c = _get_compiled(cfg, max_len, decode_impl, top_k, mesh,
                                profile, self.tokens_per_step,
                                self.speculative,
                                get_drafter(draft) if self.speculative
                                else None, donate, self.faults, kv_layout,
                                self.metrics)
        self.drafter = self._c.drafter
        self.params = (params if mesh is None
                       else jax.device_put(params, self._c.param_sharding))
        self.scheduler = Scheduler(
            max_prefill_tokens=max_prefill_tokens, pad_to=pad_to,
            slot_quantum=self._c.slot_quantum(batch_slots),
            max_prompt_len=max_prompt_len, vocab_size=cfg.vocab_size)
        self.max_pending = max_pending
        self.spec_min_acceptance = float(spec_min_acceptance)
        self.spec_resume_acceptance = float(
            spec_min_acceptance if spec_resume_acceptance is None
            else spec_resume_acceptance)
        self.spec_retry_blocks = spec_retry_blocks
        self._acc_window: Deque[Tuple[int, int]] = collections.deque(
            maxlen=max(1, spec_acceptance_window))
        self._spec_off = False            # degradation-ladder state
        self._blocks_since_spec = 0
        self._hist_stale = False          # drafter history vs slot_out
        self._fallback_warned = False
        self._cache_poison_applied: set = set()
        self._faults_fired: set = set()   # slots whose logit fault fired
        self._run_t0: Optional[float] = None
        if kv_layout == "paged":
            from repro.serving.paged import PagedManager
            self._paged: Optional[PagedManager] = PagedManager(
                Mod.paged_layout(cfg, max_len, self._c.lookahead),
                batch_slots, mode="shared" if mesh is None else "local")
        else:
            self._paged = None
        # next ring-write token position per slot (paged COW planning)
        self._slot_pos = np.zeros((batch_slots,), np.int64)
        self._retry_counts: Dict[int, int] = {}
        self._readmit: List[Request] = []

        self.caches = self._c.fresh_slot_caches(batch_slots)
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_last = np.zeros((batch_slots,), np.int32)
        self.slot_budget = np.zeros((batch_slots,), np.int32)
        self.slot_temp = np.zeros((batch_slots,), np.float32)
        if self.speculative:
            self.slot_hist, self.slot_hcnt = \
                self.drafter.init_state(batch_slots)
        # device-staged copies of the per-slot decode vectors; None means
        # "stale, rebuild from the host mirrors" (set by every admission)
        self._dev: Optional[Dict[str, Any]] = None
        self._completed: List[Result] = []
        # device metrics carry (metrics=True): persistent OUTSIDE _dev so
        # admission restages never reset counters; donated per block like
        # the caches, read only via device_metrics()'s explicit sync
        self._mx: Optional[Dict[str, Any]] = None
        if self.metrics:
            self._stage_metrics()
        # host-side lifecycle tracer (always on — O(1) Python per hook,
        # zero device work) + its subscription to the unified degradation
        # bus: the tracer's bounded `events` ring sees every record_event
        # without consuming the bus (tests/benches still drain it)
        self.tracer = Tracer(capacity=trace_capacity)
        TEV.BUS.subscribe(self.tracer.on_bus_event)
        # decode telemetry (accumulated across run()/step() calls):
        # spec_steps counts executed verify dispatches, draft_proposed /
        # draft_accepted count drafts offered vs kept (acceptance_rate).
        # PER-ATTEMPT vs PER-REQUEST accounting (the retry drift fix):
        # tokens_emitted counts every token produced by decode steps —
        # WORK, including tokens a failed attempt discarded before its
        # readmission; tokens_delivered counts tokens in finalized
        # Results — exactly once per request, whatever max_retries did.
        # The resilience counters mirror the degradation-event bus
        # (faults.consume_events) so a bench/test can assert "nothing
        # degraded" from either side.
        self.stats = {"spec_steps": 0, "draft_proposed": 0,
                      "draft_accepted": 0, "tokens_emitted": 0,
                      "tokens_delivered": 0,
                      "quarantined": 0, "rejected": 0, "deadline": 0,
                      "failed": 0, "kernel_fallbacks": 0,
                      "spec_autodisable": 0, "spec_resume": 0,
                      "readmitted": 0, "prefill_tokens_computed": 0,
                      "prefill_prefix_shared": 0, "cow_moves": 0}

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the verifier kept."""
        p = self.stats["draft_proposed"]
        return self.stats["draft_accepted"] / p if p else 0.0

    # -------------------------------------------------------- observability --
    def _stage_metrics(self):
        """(Re)create the device metrics carry, zeroed, placed to match the
        scan in_shardings (the guarded dispatch may not reshard)."""
        self._mx = MX.init_metrics(self.slots)
        if self.mesh is not None:
            veci = self._c.batch_sharding(
                self._c._sds((self.slots,)), self.slots)
            self._mx = jax.device_put(
                self._mx, MX.metrics_shardings(veci, self._c._rep))

    def device_metrics(self, per_slot: bool = False) -> Dict[str, Any]:
        """Read the device-resident counters — an EXPLICIT, scheduled host
        sync outside the decode transfer guard (the one place layer-1
        telemetry touches the host). Returns int totals, or the raw
        (slots,) vectors with per_slot=True. Empty when metrics=False."""
        if self._mx is None:
            return {}
        host = {k: np.asarray(v) for k, v in self._mx.items()}
        if per_slot:
            return host
        return {k: int(v.sum()) if v.ndim else int(v)
                for k, v in host.items()}

    def metrics_text(self) -> str:
        """Prometheus text exposition of host stats + device counters +
        tracer latency quantiles (one scrape endpoint's worth)."""
        counters = dict(self.stats)
        counters.update({f"device_{k}": v
                         for k, v in self.device_metrics().items()})
        counters.update(self.paged_stats())
        doc = {f"device_{k}": f"{v} (device-resident counter)"
               for k, v in MX.COUNTER_DOC.items()}
        return prometheus_text(counters, self.tracer.latency_summary(),
                               doc=doc)

    def chrome_trace(self) -> dict:
        """Chrome-trace JSON of the tracer's retained window (load in
        chrome://tracing / Perfetto)."""
        return self.tracer.chrome_trace(metadata={
            "model": self.cfg.name, "slots": self.slots,
            "decode_impl": self.decode_impl,
            "speculative": self.speculative, "kv_layout": self.kv_layout,
            "metrics": self.metrics})

    def snapshot(self) -> Dict[str, Any]:
        """One observability snapshot (the `swat-scope` CLI's payload):
        host stats, device counters, latency quantiles, pool occupancy."""
        return {"stats": dict(self.stats),
                "device": self.device_metrics(),
                "latency": self.tracer.latency_summary(),
                "paged": self.paged_stats(),
                "ring_modulus": self._c.ring_mod,
                "dropped_trace_records": self.tracer.dropped_requests}

    # --------------------------------------------------------- resilience --
    _STATUS_COUNTER = {"rejected": "rejected", "poisoned": "quarantined",
                       "deadline": "deadline", "failed": "failed"}
    _STATUS_EVENT = {"rejected": "request_rejected",
                     "poisoned": "slot_quarantined",
                     "deadline": "deadline_expired",
                     "failed": "request_failed"}

    def _finish(self, rid: int, tokens: List[int], status: str,
                reason: str = "") -> Result:
        """Finalize one request into self._completed (the ONLY result
        store — run() drains it, so a mid-loop exception never loses
        finished work) and mirror non-ok statuses to stats + event bus."""
        res = Result(rid, tokens, status=status, reason=reason,
                     retries=self._retry_counts.get(rid, 0))
        self._completed.append(res)
        # delivered = tokens in the FINAL Result, counted exactly once per
        # request (readmitted attempts never reach here); contrast
        # tokens_emitted, the per-attempt work counter
        self.stats["tokens_delivered"] += len(tokens)
        self.tracer.on_finish(rid, status, len(tokens))
        if status != "ok":
            self.stats[self._STATUS_COUNTER[status]] += 1
            F.record_event(self._STATUS_EVENT[status], rid=rid,
                           reason=reason)
        return res

    def take_completed(self) -> List[Result]:
        """Drain finished Results (rid order). After an exception escaped
        `run()`, this recovers everything that finished before it."""
        out, self._completed = self._completed, []
        return sorted(out, key=lambda r: r.rid)

    def _drain_rejections(self):
        for req, reason in self.scheduler.take_rejected():
            self._finish(req.rid, [], "rejected", reason)

    def _free_slot(self, s: int):
        self.slot_free[s] = True
        self.slot_req[s] = None
        self.slot_budget[s] = 0
        self._slot_pos[s] = 0
        if self._paged is not None:
            # release refcounts and park the table on the slot's scratch
            # block; the park is flushed to the device before the next
            # decode dispatch (manager.dirty) or by the next admission's
            # full-table push — nothing runs in between
            self._paged.free(s)

    def _expire_deadlines(self, pending: Deque[Request]):
        """Finalize requests whose deadline (seconds since run()
        submission) passed — queued ones with no tokens, live ones with
        their partial output. Block-boundary resolution."""
        if self._run_t0 is None:
            return
        elapsed = time.monotonic() - self._run_t0
        if pending and any(r.deadline is not None for r in pending):
            keep = []
            for r in pending:
                if r.deadline is not None and elapsed > r.deadline:
                    self._finish(r.rid, [], "deadline",
                                 f"deadline {r.deadline}s expired in queue")
                else:
                    keep.append(r)
            pending.clear()
            pending.extend(keep)
        freed = False
        for s in range(self.slots):
            req = self.slot_req[s]
            if (req is not None and req.deadline is not None
                    and elapsed > req.deadline):
                self._finish(req.rid, self.slot_out[s], "deadline",
                             f"deadline {req.deadline}s expired mid-decode")
                self._free_slot(s)
                freed = True
        if freed:
            self._dev = None      # host slot state changed: restage

    # ------------------------------------------------------------ prefill --
    def _prefill_into(self, plan: PrefillPlan, slots: List[int]):
        self.tracer.on_admit([r.rid for r in plan.requests])
        n, l_pad = plan.tokens.shape
        tokens = jnp.asarray(plan.tokens)
        lengths = jnp.asarray(plan.lengths)
        prefix = 0
        if (self.share_prefix and n >= 2 and self.prefill_chunk
                and plan.prefix_len >= self.share_min_prefix):
            prefix = int(plan.prefix_len)
        if prefix:
            # prefill the shared prefix ONCE on one row, broadcast the
            # cache to every admitted row, then walk only the per-row
            # suffixes — prefill compute drops from sum(len_i) to
            # prefix + sum(len_i - prefix)
            out1, c1 = self._c.prefill(1)(
                self.params, tokens[:1, :prefix],
                jnp.full((1,), prefix, jnp.int32))
            last, caches = self._c.broadcast_prefix(n)(c1, out1[:, 0])
            for p in range(prefix, l_pad, self.prefill_chunk):
                chunk = tokens[:, p:p + self.prefill_chunk]
                last, caches = self._c.chunk(n)(
                    self.params, caches, chunk, jnp.int32(p), lengths, last)
            logits = last
            self.stats["prefill_prefix_shared"] += 1
            self.stats["prefill_tokens_computed"] += prefix + int(
                sum(max(int(l) - prefix, 0) for l in plan.lengths))
        elif self.prefill_chunk and l_pad > self.prefill_chunk:
            caches = self._c.fresh_caches(n)
            last = jnp.zeros((n, self.cfg.vocab_size), jnp.float32)
            for p in range(0, l_pad, self.prefill_chunk):
                chunk = tokens[:, p:p + self.prefill_chunk]
                last, caches = self._c.chunk(n)(
                    self.params, caches, chunk, jnp.int32(p), lengths, last)
            logits = last
            self.stats["prefill_tokens_computed"] += int(
                sum(int(l) for l in plan.lengths))
        else:
            out, caches = self._c.prefill(n)(self.params, tokens, lengths)
            logits = out[:, 0]
            self.stats["prefill_tokens_computed"] += int(
                sum(int(l) for l in plan.lengths))
        temps = np.asarray([r.temperature for r in plan.requests], np.float32)
        self.key, sub = jax.random.split(self.key)
        first = np.asarray(self._c.sample(n)(sub, logits, jnp.asarray(temps)))
        self.tracer.on_first_token([r.rid for r in plan.requests])
        if self._paged is not None:
            # shareability is judged against EVERY position prefill wrote —
            # padded rows carry (masked) garbage up to l_pad, so the
            # conservative write-span per row is [prefix, l_pad)
            self._paged.admit(slots, [l_pad] * n, prefix_len=prefix)
            ptables = {f"l{i}": jnp.asarray(t)
                       for i, t in self._paged.tables.items()}
            self.caches = self._c.insert_paged(self.slots, n)(
                self.caches, caches, jnp.asarray(slots, jnp.int32), ptables)
        else:
            self.caches = self._c.insert(self.slots, n)(
                self.caches, caches, jnp.asarray(slots, jnp.int32))
        for s, l in zip(slots, plan.lengths):
            self._slot_pos[s] = int(l)
        for i, (req, s) in enumerate(zip(plan.requests, slots)):
            self.slot_out[s] = [int(first[i])]
            self.slot_last[s] = int(first[i])
            self.slot_temp[s] = req.temperature
            if self.speculative:
                # drafter context = the prompt plus the first sampled
                # token (hist must end at slot_last — propose() matches
                # the suffix that includes the pending token). slot_hist
                # may be device-resident after a decode block; pull it
                # back to numpy to write the seeded row.
                self.slot_hist = np.array(self.slot_hist, np.int32)
                self.slot_hcnt = np.array(self.slot_hcnt, np.int32)
                row, cnt = self.drafter.seed_row(
                    np.concatenate([req.prompt, [first[i]]]))
                self.slot_hist[s] = row
                self.slot_hcnt[s] = cnt
            budget = req.max_new_tokens - 1
            if budget <= 0:
                self._finish(req.rid, self.slot_out[s], "ok")
                self._free_slot(s)
            else:
                self.slot_free[s] = False
                self.slot_req[s] = req
                self.slot_budget[s] = budget
        self._dev = None          # host mirrors changed; restage on device

    def _admit(self, pending: Deque[Request]):
        while pending:
            free = [s for s in range(self.slots) if self.slot_free[s]]
            if not free:
                break
            width = len(free) if self.batch_prefill else 1
            plan = self.scheduler.plan(pending, width)
            if plan is None:
                break
            self._prefill_into(plan, free[:len(plan.requests)])
        # requests the scheduler refused (empty/oversized/out-of-vocab
        # prompts) finalize as status "rejected" — they never crash a batch
        self._drain_rejections()

    # ------------------------------------------------------------- decode --
    def _spec_mode(self) -> Tuple[bool, bool]:
        """(run speculatively this block?, is this a hysteresis probe?)
        under the acceptance ladder. Auto-disabled engines decode
        sequentially (same greedy tokens, no wasted verify lanes) and
        periodically probe one spec block to earn speculation back."""
        if not self.speculative:
            return False, False
        if not self._spec_off:
            return True, False
        self._blocks_since_spec += 1
        if self.spec_retry_blocks and \
                self._blocks_since_spec >= self.spec_retry_blocks:
            return True, True
        return False, False

    def _spec_ladder_update(self, prop: int, acc: int, probe: bool):
        """Feed one spec block's acceptance into the ladder."""
        if self.spec_min_acceptance <= 0:
            return
        if probe:
            rate = acc / prop if prop else 0.0
            if rate >= self.spec_resume_acceptance:
                self._spec_off = False
                self.stats["spec_resume"] += 1
                F.record_event("spec_resume", rate=rate)
                self._acc_window.clear()
            else:
                self._blocks_since_spec = 0    # stay off; probe again later
            return
        self._acc_window.append((prop, acc))
        wp = sum(p for p, _ in self._acc_window)
        wa = sum(a for _, a in self._acc_window)
        if wp >= 2 * self.speculative and wa / wp < self.spec_min_acceptance:
            self._spec_off = True
            self._blocks_since_spec = 0
            self._acc_window.clear()
            self.stats["spec_autodisable"] += 1
            F.record_event("spec_autodisable", rate=wa / wp)

    def _reseed_history(self, live: List[int]):
        """Sequential-fallback blocks emit tokens the drafter never
        observed; rebuild each live slot's history (prompt + full output)
        before the next speculative block."""
        hist = np.array(self.slot_hist, np.int32)
        hcnt = np.array(self.slot_hcnt, np.int32)
        for s in live:
            row, cnt = self.drafter.seed_row(
                np.concatenate([self.slot_req[s].prompt, self.slot_out[s]]))
            hist[s], hcnt[s] = row, cnt
        self.slot_hist, self.slot_hcnt = hist, hcnt
        self._hist_stale = False
        self._dev = None

    def _apply_cache_poisons(self, live: List[int]):
        """Chaos: smear NaN over a slot's ring K caches once it has
        emitted its trigger count — corruption the guard didn't see born.
        The next attention read propagates it into that slot's logits,
        where the in-scan guard quarantines it."""
        if not self.faults.poison_cache:
            return
        tokens_done = [len(self.slot_out[s]) if not self.slot_free[s] else -1
                       for s in range(self.slots)]
        for s in self.faults.cache_poisons_due(
                self.slots, tokens_done, self._cache_poison_applied):
            self._cache_poison_applied.add(s)
            if self._paged is not None:
                # the slot must own its blocks exclusively before NaN-ing:
                # poisoning a refcount-shared prefix block would quarantine
                # every sharer, not the targeted slot
                self._paged_flush(self._paged.force_private(s))
                self.caches = _poison_slot_k_paged(self.caches, s)
            else:
                self.caches = _poison_slot_k(self.caches, s)
            F.record_event("cache_poisoned", slot=s)

    # --------------------------------------------------------------- paged --
    def _paged_flush(self, moves: Dict[int, List[Tuple[int, int]]]):
        """Dispatch COW block copies + the host table mirror to the device
        (outside the decode transfer guard — tables are an explicit,
        scheduled host->device push). No-op when nothing changed."""
        pm = self._paged
        m = max((len(v) for v in moves.values()), default=0)
        if m == 0 and not pm.dirty:
            return
        self.stats["cow_moves"] += sum(len(v) for v in moves.values())
        tables = {f"l{i}": jnp.asarray(t) for i, t in pm.tables.items()}
        if m:
            # one bucketed move width per compile; layers with fewer moves
            # pad with scratch->scratch self-copies (scratch ids are never
            # real destinations, so padding can't collide with a move)
            mpad = 1 << (m - 1).bit_length()
            srcs, dsts = {}, {}
            for i, mv in moves.items():
                sc = pm.scratch_id(i, 0)
                pad = mpad - len(mv)
                srcs[f"l{i}"] = jnp.asarray(
                    [a for a, _ in mv] + [sc] * pad, jnp.int32)
                dsts[f"l{i}"] = jnp.asarray(
                    [b for _, b in mv] + [sc] * pad, jnp.int32)
            self.caches = self._c.fixup(self.slots, mpad)(
                self.caches, tables, srcs, dsts)
        else:
            self.caches = self._c.fixup(self.slots, 0)(
                self.caches, tables, {}, {})
        pm.dirty = False

    def _paged_sync(self, n: int):
        """Pre-block paged maintenance: plan copy-on-write for every ring
        row this block can write ([pos, pos+n*T) per live slot) and flush
        moves + any parked tables."""
        pm = self._paged
        positions = {s: int(self._slot_pos[s]) for s in range(self.slots)
                     if not self.slot_free[s]}
        self._paged_flush(
            pm.cow_moves(positions, n * self._c.tokens_per_step))

    def paged_stats(self) -> Dict[str, int]:
        """Block-pool occupancy (shared-prefix dedup shows up here)."""
        if self._paged is None:
            return {}
        return {"blocks_in_use": self._paged.blocks_in_use(),
                "blocks_total": self._paged.blocks_total()}

    def _kernel_fallback(self, err, n: int) -> List[Result]:
        """Rung one of the degradation ladder: the Pallas decode kernel
        failed to dispatch — swap this engine to the reference impl (for
        good: `_get_compiled` keys by impl, so this is a rebuild, not a
        recompile storm) and retry the block. The injected failure raises
        at trace time, BEFORE the donated ring caches are consumed, so the
        retry serves every in-flight slot untouched; if a mid-execution
        failure DID consume the donation, the slots cannot be resumed and
        finalize as status "failed" instead of silently garbage."""
        self.stats["kernel_fallbacks"] += 1
        F.record_event("pallas_fallback", error=str(err))
        if not self._fallback_warned:
            self._fallback_warned = True
            warnings.warn(
                "pallas decode dispatch failed; this engine now decodes "
                f"with the reference impl ({err})",
                RuntimeWarning, stacklevel=3)
        self.decode_impl = "ref"
        self._c = _get_compiled(self.cfg, self.max_len, "ref", self.top_k,
                                self.mesh, self.profile,
                                self.tokens_per_step, self.speculative,
                                self.drafter, self._c.donate, self.faults,
                                self.kv_layout, self.metrics)
        deleted = any(getattr(l, "is_deleted", lambda: False)()
                      for l in jax.tree.leaves(self.caches))
        if self.metrics and any(getattr(l, "is_deleted", lambda: False)()
                                for l in jax.tree.leaves(self._mx)):
            # the failed dispatch consumed the donated metrics carry:
            # counters restart from zero (documented loss — rare, and the
            # kernel_fallbacks stat records that it happened)
            self._stage_metrics()
        if not deleted:
            return self._decode_block(n)
        done = []
        for s in range(self.slots):
            if not self.slot_free[s]:
                req = self.slot_req[s]
                used = self._retry_counts.get(req.rid, 0)
                if used < req.max_retries:
                    # bounded retry: requeue through the normal admission
                    # path — the retry re-prefills from the prompt (the
                    # slot's partial output died with the donated caches)
                    self._retry_counts[req.rid] = used + 1
                    self.stats["readmitted"] += 1
                    F.record_event("request_readmitted", rid=req.rid,
                                   retry=used + 1)
                    self._readmit.append(req)
                    self._free_slot(s)
                else:
                    done.append(self._finish(
                        req.rid, self.slot_out[s], "failed",
                        "kernel dispatch failed after cache donation"))
                    self._free_slot(s)
        if self._paged is not None:
            self._paged.reset()
        self.caches = self._c.fresh_slot_caches(self.slots)
        self._dev = None
        return done

    def _decode_block(self, n: int) -> List[Result]:
        """Run n decode steps on-device (one host sync), then retire
        finished slots. Speculative engines run n draft/verify/accept
        steps instead, each emitting 1..speculative+1 tokens per slot.

        Resilience: the compiled scan body carries a per-slot `poisoned`
        flag — a slot whose logits go non-finite stops emitting on device
        and is QUARANTINED here (finalized as status "poisoned", slot
        freed, every other slot untouched); a Pallas dispatch failure
        falls back to the ref impl (`_kernel_fallback`)."""
        live = [s for s in range(self.slots) if not self.slot_free[s]]
        if not live:
            return []
        self._apply_cache_poisons(live)
        if self._paged is not None:
            self._paged_sync(n)
        use_spec, probe = self._spec_mode()
        if use_spec and self._hist_stale:
            self._reseed_history(live)
        inject = self._c.faults.has_logit_faults
        if self._dev is None:
            # (re)stage the per-slot vectors on device. Admission is the
            # only writer outside a decode block, so between consecutive
            # blocks the scan's own outputs are reused verbatim and a
            # block dispatch uploads NOTHING — host->device staging of
            # half a dozen tiny arrays costs as much as a decode step on
            # small models.
            active = np.asarray([not f for f in self.slot_free], bool)
            self._dev = dict(
                tok=jnp.asarray(self.slot_last),
                active=jnp.asarray(active),
                budget=jnp.asarray(self.slot_budget),
                temps=jnp.asarray(self.slot_temp),
                # the all-greedy fast-path predicate, precomputed on host:
                # reducing the slot-sharded temps on device would cost a
                # pred[] all-reduce in every scan step (sampling.sample)
                anyt=jnp.asarray(bool(np.any((self.slot_temp > 0)
                                             & active))),
                # freed slots never re-enter `live` without a restage, so
                # zeros here cover every slot the scan may still touch
                poisoned=jnp.zeros((self.slots,), jnp.bool_))
            if inject:
                self._dev["fin"] = jnp.asarray(self.faults.logit_countdown(
                    self.slots,
                    [len(self.slot_out[s]) if not self.slot_free[s] else 0
                     for s in range(self.slots)],
                    fired=self._faults_fired))
            if self.speculative:
                self._dev["hist"] = jnp.asarray(self.slot_hist)
                self._dev["hcnt"] = jnp.asarray(self.slot_hcnt)
            if self.mesh is not None:
                # explicit mesh placement, matching the scan in_shardings —
                # the guarded dispatch below may not reshard implicitly
                sh = self._c.slot_vector_shardings(self.slots)
                self._dev = {k: jax.device_put(v, sh[k])
                             for k, v in self._dev.items()}
        dev = self._dev
        if self.mesh is not None:
            # admission splits self.key on host (default placement); the
            # scan consumes it replicated — re-place explicitly (no-op
            # between consecutive blocks: scan outputs carry _rep already)
            self.key = jax.device_put(self.key, self._c._rep)
        # steady-state guard: every operand is device-resident by now, so
        # the block dispatch must not transfer ANYTHING implicitly — a
        # host-synced scalar or np-array operand inside this loop taxes
        # every block and is exactly what the analyzer's host-sync rule
        # lints for. Explicit syncs (np.asarray on the outputs below) stay
        # legal under "disallow".
        guard = (jax.transfer_guard("disallow") if self.transfer_guard
                 else contextlib.nullcontext())
        extra = (dev["fin"],) if inject else ()
        # metrics carry rides LAST (donated); its updated pytree comes
        # back last and is simply re-fed next block — no host sync here
        mextra = (self._mx,) if self.metrics else ()
        t0 = self.tracer.clock()
        try:
            if use_spec:
                with guard:
                    outs = self._c.spec_scan(n, self.slots)(
                        self.params, self.caches, dev["tok"], dev["active"],
                        dev["budget"], dev["temps"], dev["anyt"], self.key,
                        dev["hist"], dev["hcnt"], dev["poisoned"],
                        *extra, *mextra)
                (self.caches, tok, active_out, budget, self.key, hist, hcnt,
                 toks, emit, steps, poisoned) = outs[:11]
                if inject:
                    dev["fin"] = outs[11]
                if self.metrics:
                    self._mx = outs[11 + (1 if inject else 0)]
                # drafter state stays device-resident too; _prefill_into
                # materializes to numpy only when it needs to seed a row
                self.slot_hist = hist
                self.slot_hcnt = hcnt
                dev.update(tok=tok, active=active_out, budget=budget,
                           hist=hist, hcnt=hcnt, poisoned=poisoned)
                toks, emit = np.asarray(toks), np.asarray(emit)
                counts = emit.sum(axis=-1)                    # (n, slots)
                ran = counts >= 1
                self.stats["spec_steps"] += int(steps)
                prop = self.speculative * int(ran.sum())
                acc = int((counts[ran] - 1).sum())
                self.stats["draft_proposed"] += prop
                self.stats["draft_accepted"] += acc
                self._spec_ladder_update(prop, acc, probe)
            else:
                with guard:
                    outs = self._c.scan(n, self.slots)(
                        self.params, self.caches, dev["tok"], dev["active"],
                        dev["budget"], dev["temps"], dev["anyt"], self.key,
                        dev["poisoned"], *extra, *mextra)
                (self.caches, tok, active_out, budget, self.key, toks,
                 emit, poisoned) = outs[:8]
                if inject:
                    dev["fin"] = outs[8]
                if self.metrics:
                    self._mx = outs[8 + (1 if inject else 0)]
                dev.update(tok=tok, active=active_out, budget=budget,
                           poisoned=poisoned)
                toks, emit = np.asarray(toks), np.asarray(emit)
                if self.speculative:
                    self._hist_stale = True   # drafter history lags output
        except F.KernelDispatchError as e:
            return self._kernel_fallback(e, n)
        self.stats["tokens_emitted"] += int(emit.sum())
        # the np.asarray(emit) above IS the block's host sync — the span
        # closed here covers dispatch + device execution + drain
        self.tracer.on_block("spec" if use_spec else "seq", n, t0,
                             int(emit.sum()))
        if self._paged is not None:
            # advance the per-slot ring-write position mirror: sequential
            # steps write one row per executed step unconditionally (+n);
            # spec steps net +e after rollback, and emit rows equal e
            if use_spec:
                adv = emit.sum(axis=(0, 2))
                for s in live:
                    self._slot_pos[s] += int(adv[s])
            else:
                for s in live:
                    self._slot_pos[s] += n
        self.slot_last = np.array(tok, np.int32)      # writable host mirrors
        self.slot_budget = np.array(budget, np.int32)
        poisoned_np = np.asarray(poisoned)
        done: List[Result] = []
        for s in live:
            # row-major over (step[, position]) => chronological order
            self.slot_out[s].extend(
                int(t) for t in toks[:, s][emit[:, s]])
            if poisoned_np[s]:
                # disarm the slot's pending injections: a fault entry
                # targets one occupant, not every future tenant of the slot
                self._faults_fired.add(s)
                done.append(self._finish(
                    self.slot_req[s].rid, self.slot_out[s], "poisoned",
                    "non-finite logits; slot quarantined"))
                self._free_slot(s)
            elif self.slot_budget[s] <= 0:
                done.append(self._finish(
                    self.slot_req[s].rid, self.slot_out[s], "ok"))
                self._free_slot(s)
        return done

    def step(self) -> List[Result]:
        """One decode step for every live slot (the per-token-sync path)."""
        return self._decode_block(1)

    def _block_len(self) -> int:
        """Largest block that can't overshoot any live slot: stop at the
        earliest completion so slots free (and refill) at block boundaries
        and the RNG stream is identical for every scan_steps setting.

        Deliberate tradeoff: a short-budget request drags the whole batch
        to short blocks until it retires, and each distinct n compiles its
        own scan (bounded by scan_steps programs per model). Bucketing n
        would cut compiles but break the scan==stepwise token-for-token
        guarantee test_serving pins down; revisit if serving mixes budgets
        at scale."""
        live_budgets = [int(self.slot_budget[s]) for s in range(self.slots)
                        if not self.slot_free[s]]
        if not live_budgets:
            return 0
        floor = min(live_budgets)
        # Speculative blocks use the SAME floor: a spec step emits 1..T
        # tokens, so b steps always suffice and the per-slot budget clamp
        # plus the all-done early exit make any block length safe. Sizing
        # by ceil(b/T) instead (the fastest possible finish) assumes full
        # acceptance and collapses near-drain blocks to n=1 — a host
        # round trip per step, which is exactly the seed-engine overhead
        # batching exists to kill. A slot that finishes mid-block idles
        # until the block ends (refill latency <= scan_steps, the same
        # bound the sequential engine has).
        return max(1, min(self.scan_steps, floor))

    # --------------------------------------------------------------- run ---
    def run(self, requests: List[Request]) -> List[Result]:
        """Serve a batch to completion; one Result per request, rid order,
        each carrying a `status` (see STATUSES). Every finished request
        lands in `self._completed` the moment it finalizes — never a
        mid-loop local — so if an exception escapes this loop the caller
        recovers everything already served via `take_completed()` (the
        old code lost them: completed Results sat in a local `results`
        list the raise threw away).

        Overload: beyond `max_pending` queued requests, the tail is
        REJECTED up front (bounded-queue backpressure — an overloaded
        engine sheds load instead of buffering toward OOM). Per-request
        `deadline`s are measured from this submission and enforced at
        block boundaries."""
        self._run_t0 = time.monotonic()
        pending: Deque[Request] = collections.deque()
        for r in requests:
            self.tracer.on_submit(r.rid)
            if self.max_pending is not None and \
                    len(pending) >= self.max_pending:
                self._finish(r.rid, [], "rejected",
                             f"queue overflow (max_pending="
                             f"{self.max_pending})")
            else:
                pending.append(r)
        try:
            while pending or self._readmit or not all(self.slot_free):
                if self._readmit:
                    # failed-slot retries rejoin the queue tail: FCFS among
                    # themselves, no preemption of already-queued work
                    readd, self._readmit = self._readmit, []
                    pending.extend(readd)
                self._expire_deadlines(pending)
                self._admit(pending)
                n = self._block_len()
                if n:
                    self._decode_block(n)
        finally:
            # surface scheduler rejections even if the loop died between
            # plan() and the next _admit drain
            self._drain_rejections()
            self._run_t0 = None
        return self.take_completed()


def _poison_slot_k(caches, slot: int):
    """Overwrite one slot's ring K caches with NaN (every layer, every
    super-block) — the fault harness's cache-corruption primitive."""
    def visit(c):
        if isinstance(c, dict) and "k" in c:
            c = dict(c)
            c["k"] = c["k"].at[:, slot].set(jnp.nan)
        return c
    return jax.tree.map(visit, caches,
                        is_leaf=lambda c: isinstance(c, dict) and "k" in c)


def _poison_slot_k_paged(caches, slot: int):
    """Paged twin of `_poison_slot_k`: NaN the K pool blocks the slot's
    table references. Caller must have forced the slot's blocks private
    first (PagedManager.force_private) and flushed the moves."""
    def visit(c):
        if isinstance(c, dict) and "pk" in c:
            c = dict(c)
            if c["pk"].ndim == 6:       # local per-slot pool (S,B,nb+1,...)
                c["pk"] = c["pk"].at[:, slot].set(jnp.nan)
            else:                        # shared pool: poison the table row
                ids = c["table"][0, slot]
                c["pk"] = c["pk"].at[:, ids].set(jnp.nan)
        return c
    return jax.tree.map(visit, caches,
                        is_leaf=lambda c: isinstance(c, dict) and "pk" in c)


def ring_cache_bytes(cfg: ModelConfig, batch: int, context: int) -> int:
    """Decode-cache bytes — the paper's Fig. 3 memory comparison. Window
    attention: O(window); dense: O(context). Counts PHYSICAL rows
    (`cache_allocation`: logical capacity + the tile-rounding tail)."""
    from repro.core.layers import cache_allocation
    from repro.core.model import attn_cfg
    total = 0
    for i, kind in enumerate(cfg.layer_pattern):
        if kind.startswith("mamba"):
            spec = cfg.ssm
            h = spec.num_heads(cfg.d_model)
            total += batch * (h * spec.head_dim * spec.state_dim * 4
                              + (spec.conv_width - 1)
                              * (spec.d_inner(cfg.d_model)
                                 + 2 * spec.num_groups * spec.state_dim) * 2)
            continue
        acfg = attn_cfg(cfg, kind, index=i)
        cap = cache_allocation(acfg, context)
        total += 2 * batch * acfg.num_kv_heads * cap * acfg.head_dim * 2
    return total * cfg.num_super_blocks
