"""Batched serving engine: prefill + decode with ring KV caches.

The paper's FIFO K/V buffer is the serving-side win of window attention:
decode memory is O(window), not O(context) — SWAT's Fig. 3 linear-memory
claim. The engine demonstrates it end-to-end:

  * static batch of slots (TPU-friendly: shapes never change),
  * continuous batching lite — finished sequences release their slot, the
    next request is prefilled into it,
  * per-slot cache_len / step tracking (the caches are stacked pytrees;
    slot i's entries are batch row i),
  * greedy or temperature sampling.

For simplicity slots prefill one at a time (row-inserted into the batched
cache); decode always runs the full batch. That matches the
single-sequence-prefill / batched-decode split most production TPU servers
use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as Mod
from repro.core.types import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 8,
                 max_len: int = 4096, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.caches = Mod.init_caches(cfg, batch_slots, max_len)
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_last = np.zeros((batch_slots,), np.int32)
        self.slot_budget = np.zeros((batch_slots,), np.int32)

        self._prefill = jax.jit(
            lambda p, b: Mod.prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, b: Mod.decode_step(p, cfg, b, c))

    # ------------------------------------------------------------ slots ----
    def _insert_rows(self, caches_one, slot: int):
        """Copy batch-row 0 of a 1-sequence cache pytree into `slot`."""
        def ins(full, one):
            if full.ndim < 2 or full.shape[1] != self.slots:
                return one if full.ndim == one.ndim and full.shape == one.shape else full
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1)
        return jax.tree.map(ins, self.caches, caches_one)

    def add_request(self, req: Request) -> bool:
        try:
            slot = self.slot_free.index(True)
        except ValueError:
            return False
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        logits, caches_one = self._prefill(self.params, batch)
        self.caches = self._insert_rows(caches_one, slot)
        tok = self._sample(logits[:, 0], req.temperature)[0]
        self.slot_free[slot] = False
        self.slot_req[slot] = req
        self.slot_out[slot] = [int(tok)]
        self.slot_last[slot] = int(tok)
        self.slot_budget[slot] = req.max_new_tokens - 1
        return True

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(sub, logits / temperature))

    # ----------------------------------------------------------- decode ----
    def step(self):
        """One decode step for every live slot."""
        batch = {"tokens": jnp.asarray(self.slot_last[:, None], jnp.int32)}
        logits, self.caches = self._decode(self.params, self.caches, batch)
        toks = self._sample(logits[:, 0], 0.0)
        done: List[Result] = []
        for s in range(self.slots):
            if self.slot_free[s]:
                continue
            self.slot_out[s].append(int(toks[s]))
            self.slot_last[s] = int(toks[s])
            self.slot_budget[s] -= 1
            if self.slot_budget[s] <= 0:
                done.append(Result(self.slot_req[s].rid, self.slot_out[s]))
                self.slot_free[s] = True
                self.slot_req[s] = None
        return done

    def run(self, requests: List[Request]) -> List[Result]:
        pending = list(requests)
        results: List[Result] = []
        while pending or not all(self.slot_free):
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            if not all(self.slot_free):
                results.extend(self.step())
        return sorted(results, key=lambda r: r.rid)


def ring_cache_bytes(cfg: ModelConfig, batch: int, context: int) -> int:
    """Decode-cache bytes — the paper's Fig. 3 memory comparison. Window
    attention: O(window); dense: O(context)."""
    from repro.core.layers import cache_capacity
    from repro.core.model import attn_cfg
    total = 0
    for kind in cfg.layer_pattern:
        if kind.startswith("mamba"):
            spec = cfg.ssm
            h = spec.num_heads(cfg.d_model)
            total += batch * (h * spec.head_dim * spec.state_dim * 4
                              + (spec.conv_width - 1)
                              * (spec.d_inner(cfg.d_model)
                                 + 2 * spec.num_groups * spec.state_dim) * 2)
            continue
        acfg = attn_cfg(cfg, kind)
        cap = cache_capacity(acfg, context)
        total += 2 * batch * acfg.num_kv_heads * cap * acfg.head_dim * 2
    return total * cfg.num_super_blocks
