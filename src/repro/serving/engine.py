"""Device-resident continuous-batching serving engine.

The paper's FIFO K/V buffer is the serving-side win of window attention:
decode memory is O(window), not O(context) — SWAT's Fig. 3 linear-memory
claim. The engine turns that into throughput:

  * static batch of slots (TPU-friendly: shapes never change) with PER-SLOT
    ring write positions — every row of every cache tracks its own step, so
    slots at different depths share one batched kernel call,
  * batched, padded prefill: the scheduler packs all pending prompts that
    fit into one call (per-row `lengths` mask the padding), optionally
    chunked along the sequence axis so prefill VMEM is bounded by the chunk
    size rather than the longest prompt,
  * scan decode: N tokens per dispatch under `jax.lax.scan` with per-slot
    done/budget flags — the host syncs once per block instead of once per
    token (the seed engine's per-token round-trip),
  * per-slot temperature / top-k sampling (jitted; greedy rows take argmax).

Mesh-sharded serving (`ServingEngine(mesh=, profile=)`): every jitted entry
point carries `in_shardings`/`out_shardings` from the distributed/sharding.py
rule tables — params by `param_sharding` (placed ONCE at engine
construction), ring caches by `cache_sharding` (slot dim over ('pod','data'),
kv heads over 'model', per-slot `step` riding the slot axis), and the
per-slot decode state (`slot_last`/`slot_budget`/`slot_temp`/active flags)
by `decode_batch_sharding`. XLA then PARTITIONS decode across the mesh
instead of replicating it — the scan-decode block is the sync quantum. The
scheduler is told the slot-axis size so admitted prefill batches stay
divisible (and therefore sharded) whenever enough prompts are pending.

Determinism: the RNG key splits once per executed decode step and once per
prefill batch, in the same order whatever `scan_steps` is (blocks stop at
the earliest slot completion), so scan decode is token-for-token identical
to stepwise decode — the property test_serving.py pins down. The sharded
engine runs the same program partitioned, so it is token-for-token identical
to the single-device engine (tests/test_serving_sharded.py).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as Mod
from repro.core.types import ModelConfig
from repro.serving import sampling
from repro.serving.scheduler import PrefillPlan, Scheduler, normalize_prompt


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # any int spelling; normalized to (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0

    def __post_init__(self):
        # normalize ONCE at the boundary: a (1, L) / list-of-lists prompt
        # used to len()-measure as 1 and crash (or mis-pad) at batch fill
        self.prompt = normalize_prompt(self.prompt)


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class _Compiled:
    """Jitted functions shared by every engine over the same
    (cfg, max_len, decode_impl, top_k, mesh, profile, tokens_per_step):
    compiles are per-model, engines are cheap per-session objects
    (constructing a second engine must not pay XLA again — `_get_compiled`
    memoizes these).

    tokens_per_step sizes the ring lookahead (caches get T-1 extra ring
    rows so a T-token decode step never evicts an in-window token) and is
    part of the compile identity: every cache shape, prefill, and scan
    program depends on it — the speculative-decode hook.

    With a mesh, every function is keyed by its batch-row count so each
    shape gets exact `in_shardings`/`out_shardings` (the sharding rules are
    divisibility-aware, so specs depend on the concrete row count)."""

    def __init__(self, cfg: ModelConfig, max_len: int, decode_impl: str,
                 top_k: int, mesh=None, profile: str = "tp",
                 tokens_per_step: int = 1):
        self.cfg, self.max_len = cfg, max_len
        self.decode_impl, self.top_k = decode_impl, top_k
        self.tokens_per_step = tokens_per_step
        self.lookahead = tokens_per_step - 1
        self.mesh, self.profile = mesh, profile
        if mesh is not None:
            from repro.distributed import sharding as Sh
            self._Sh = Sh
            pshapes = jax.eval_shape(
                lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
            self.param_sharding = Sh.param_sharding(pshapes, mesh, profile)
            self._rep = Sh.replicated(mesh)
        else:
            self._Sh = None
            self.param_sharding = None
            self._rep = None
        self._prefill_fns: Dict[int, Any] = {}
        self._chunk_fns: Dict[int, Any] = {}
        self._insert_fns: Dict[Tuple[int, int], Any] = {}
        self._sample_fns: Dict[int, Any] = {}
        self._scan_fns: Dict[Tuple[int, int], Any] = {}
        self._init_fns: Dict[int, Any] = {}

    # ------------------------------------------------------- sharding maps --
    def cache_sharding(self, n: int):
        shapes = jax.eval_shape(
            lambda: Mod.init_caches(self.cfg, n, self.max_len,
                                    lookahead=self.lookahead))
        return self._Sh.cache_sharding(shapes, self.mesh)

    def batch_sharding(self, shapes, n: int, slot_dim: int = 0):
        """decode_batch_sharding over a pytree of ShapeDtypeStructs."""
        return self._Sh.decode_batch_sharding(shapes, self.mesh, n,
                                              slot_dim=slot_dim)

    def _sds(self, shape, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(shape, dtype)

    def slot_quantum(self, slots: int) -> int:
        """Slot-axis size when the engine's slot count shards over it —
        the scheduler keeps prefill batches divisible by this."""
        if self.mesh is None:
            return 1
        size = 1
        for a in ("pod", "data"):
            if a in self.mesh.axis_names:
                size *= self.mesh.shape[a]
        return size if size > 1 and slots % size == 0 else 1

    def _act_sharding(self, n: int):
        if self.mesh is None:
            return None
        return self.batch_sharding(
            self._sds((n, 1, self.cfg.d_model), jnp.float32), n)

    # ------------------------------------------------------------ prefill --
    def prefill(self, n: int):
        if n not in self._prefill_fns:
            act = self._act_sharding(n)

            def fn(p, tok, lens):
                return Mod.prefill(p, self.cfg, {"tokens": tok},
                                   max_len=self.max_len, lengths=lens,
                                   act_sharding=act,
                                   lookahead=self.lookahead)
            if self.mesh is None:
                self._prefill_fns[n] = jax.jit(fn)
            else:
                vec = self.batch_sharding(self._sds((n,)), n)
                tok_sh = self.batch_sharding(self._sds((n, 1)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, 1, self.cfg.vocab_size), jnp.float32), n)
                self._prefill_fns[n] = jax.jit(
                    fn,
                    in_shardings=(self.param_sharding, tok_sh, vec),
                    out_shardings=(logit_sh, self.cache_sharding(n)))
        return self._prefill_fns[n]

    def chunk(self, n: int):
        if n not in self._chunk_fns:
            act = self._act_sharding(n)
            fn = functools.partial(self._chunk_impl, act_sharding=act)
            if self.mesh is None:
                self._chunk_fns[n] = jax.jit(fn)
            else:
                vec = self.batch_sharding(self._sds((n,)), n)
                tok_sh = self.batch_sharding(self._sds((n, 1)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, self.cfg.vocab_size), jnp.float32), n)
                cache_sh = self.cache_sharding(n)
                self._chunk_fns[n] = jax.jit(
                    fn,
                    in_shardings=(self.param_sharding, cache_sh, tok_sh,
                                  self._rep, vec, logit_sh),
                    out_shardings=(logit_sh, cache_sh))
        return self._chunk_fns[n]

    def _chunk_impl(self, params, caches, tok, pos0, lengths, last_logits,
                    act_sharding=None):
        """One prefill chunk + carry of each row's last-real-token logits
        (pos0 is traced: one compile serves every chunk index). Only the
        gathered (B, 1, D) row is unembedded — never the whole chunk."""
        x, caches = Mod.prefill_chunk(
            params, self.cfg, {"tokens": tok}, caches, pos0, lengths,
            act_sharding=act_sharding, lookahead=self.lookahead)
        t = tok.shape[1]
        tpos = lengths - 1 - pos0
        hit = (tpos >= 0) & (tpos < t)
        xsel = jnp.take_along_axis(
            x, jnp.broadcast_to(
                jnp.clip(tpos, 0, t - 1)[:, None, None],
                (x.shape[0], 1, x.shape[2])), axis=1)
        sel = Mod._unembed(params, self.cfg, xsel)[:, 0]
        return jnp.where(hit[:, None], sel, last_logits), caches

    def insert(self, slots: int, n: int):
        key = (slots, n)
        if key not in self._insert_fns:
            def fn(full, one, idx):
                return jax.tree.map(
                    lambda f, o: f.at[:, idx].set(o.astype(f.dtype)),
                    full, one)
            if self.mesh is None:
                self._insert_fns[key] = jax.jit(fn)
            else:
                self._insert_fns[key] = jax.jit(
                    fn,
                    in_shardings=(self.cache_sharding(slots),
                                  self.cache_sharding(n), self._rep),
                    out_shardings=self.cache_sharding(slots))
        return self._insert_fns[key]

    def sample(self, n: int):
        if n not in self._sample_fns:
            fn = functools.partial(sampling.sample, top_k=self.top_k)
            if self.mesh is None:
                self._sample_fns[n] = jax.jit(fn)
            else:
                vecf = self.batch_sharding(self._sds((n,), jnp.float32), n)
                veci = self.batch_sharding(self._sds((n,)), n)
                logit_sh = self.batch_sharding(
                    self._sds((n, self.cfg.vocab_size), jnp.float32), n)
                self._sample_fns[n] = jax.jit(
                    fn, in_shardings=(self._rep, logit_sh, vecf),
                    out_shardings=veci)
        return self._sample_fns[n]

    def fresh_caches(self, n: int):
        if n not in self._init_fns:
            out_sh = None if self.mesh is None else self.cache_sharding(n)
            self._init_fns[n] = jax.jit(
                lambda: Mod.init_caches(self.cfg, n, self.max_len,
                                        lookahead=self.lookahead),
                out_shardings=out_sh)
        return self._init_fns[n]()

    # ------------------------------------------------------------- decode --
    def scan(self, n: int, slots: int):
        key = (n, slots)
        if key not in self._scan_fns:
            self._scan_fns[key] = self._make_scan(n, slots)
        return self._scan_fns[key]

    def _make_scan(self, n: int, slots: int):
        cfg, impl, top_k = self.cfg, self.decode_impl, self.top_k
        lookahead = self.lookahead
        act = self._act_sharding(slots)

        def fn(params, caches, tok, active, budget, temps, key):
            def body(carry, _):
                caches, tok, active, budget, key = carry
                logits, caches = Mod.decode_step(
                    params, cfg, {"tokens": tok[:, None]}, caches, impl=impl,
                    act_sharding=act, lookahead=lookahead)
                key, sub = jax.random.split(key)
                nxt = sampling.sample(sub, logits[:, 0], temps, top_k)
                nxt = jnp.where(active, nxt, tok)
                emitted = active
                budget = budget - active.astype(jnp.int32)
                active = active & (budget > 0)
                return (caches, nxt, active, budget, key), (nxt, emitted)

            carry, (toks, emit) = jax.lax.scan(
                body, (caches, tok, active, budget, key), None, length=n)
            caches, tok, active, budget, key = carry
            return caches, tok, active, budget, key, toks, emit

        if self.mesh is None:
            return jax.jit(fn)
        cache_sh = self.cache_sharding(slots)
        veci = self.batch_sharding(self._sds((slots,)), slots)
        vecb = self.batch_sharding(self._sds((slots,), jnp.bool_), slots)
        vecf = self.batch_sharding(self._sds((slots,), jnp.float32), slots)
        blk = self.batch_sharding(self._sds((n, slots)), slots, slot_dim=1)
        return jax.jit(
            fn,
            in_shardings=(self.param_sharding, cache_sh, veci, vecb, veci,
                          vecf, self._rep),
            out_shardings=(cache_sh, veci, vecb, veci, self._rep, blk, blk))


@functools.lru_cache(maxsize=16)
def _get_compiled(cfg: ModelConfig, max_len: int, decode_impl: str,
                  top_k: int, mesh=None, profile: str = "tp",
                  tokens_per_step: int = 1) -> _Compiled:
    return _Compiled(cfg, max_len, decode_impl, top_k, mesh, profile,
                     tokens_per_step)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 8,
                 max_len: int = 4096, seed: int = 0, scan_steps: int = 8,
                 batch_prefill: bool = True, prefill_chunk: int = 0,
                 max_prefill_tokens: int = 8192, pad_to: int = 16,
                 top_k: int = 0, decode_impl: str = "ref",
                 mesh=None, profile: str = "tp", tokens_per_step: int = 1):
        """scan_steps=1 degenerates to the seed engine's per-token host
        sync; prefill_chunk=0 disables sequence-axis chunking (single-shot
        batched prefill); batch_prefill=False admits one prompt per prefill
        call (the seed behavior, kept for benchmarking).

        tokens_per_step: ring lookahead for multi-token decode steps — the
        caches carry T-1 extra ring rows and every compiled entry point is
        keyed by it, so a future speculative-decode step can verify T draft
        tokens per dispatch on these caches. Generated tokens are unchanged
        (the positional window mask hides the extra ring depth); the decode
        loop itself still emits one token per scan step.

        mesh: optional jax.sharding.Mesh — params are placed once at
        construction (`param_sharding(profile)`), caches/decode state carry
        the serving sharding rules, and every jitted call runs partitioned.
        batch_slots should be a multiple of the slot-axis size
        (('pod',)'data') for the slot dim to actually shard; indivisible
        counts degrade gracefully to replication."""
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.scan_steps = max(1, scan_steps)
        self.batch_prefill = batch_prefill
        self.prefill_chunk = (prefill_chunk
                              if Mod.prefill_chunkable(cfg) else 0)
        self.top_k = top_k
        self.decode_impl = decode_impl
        self.tokens_per_step = max(1, tokens_per_step)
        self.mesh, self.profile = mesh, profile
        self.key = jax.random.PRNGKey(seed)
        self._c = _get_compiled(cfg, max_len, decode_impl, top_k, mesh,
                                profile, self.tokens_per_step)
        self.params = (params if mesh is None
                       else jax.device_put(params, self._c.param_sharding))
        self.scheduler = Scheduler(
            max_prefill_tokens=max_prefill_tokens, pad_to=pad_to,
            slot_quantum=self._c.slot_quantum(batch_slots))

        self.caches = self._c.fresh_caches(batch_slots)
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_last = np.zeros((batch_slots,), np.int32)
        self.slot_budget = np.zeros((batch_slots,), np.int32)
        self.slot_temp = np.zeros((batch_slots,), np.float32)
        self._completed: List[Result] = []

    # ------------------------------------------------------------ prefill --
    def _prefill_into(self, plan: PrefillPlan, slots: List[int]):
        n, l_pad = plan.tokens.shape
        tokens = jnp.asarray(plan.tokens)
        lengths = jnp.asarray(plan.lengths)
        if self.prefill_chunk and l_pad > self.prefill_chunk:
            caches = self._c.fresh_caches(n)
            last = jnp.zeros((n, self.cfg.vocab_size), jnp.float32)
            for p in range(0, l_pad, self.prefill_chunk):
                chunk = tokens[:, p:p + self.prefill_chunk]
                last, caches = self._c.chunk(n)(
                    self.params, caches, chunk, jnp.int32(p), lengths, last)
            logits = last
        else:
            out, caches = self._c.prefill(n)(self.params, tokens, lengths)
            logits = out[:, 0]
        temps = np.asarray([r.temperature for r in plan.requests], np.float32)
        self.key, sub = jax.random.split(self.key)
        first = np.asarray(self._c.sample(n)(sub, logits, jnp.asarray(temps)))
        self.caches = self._c.insert(self.slots, n)(
            self.caches, caches, jnp.asarray(slots, jnp.int32))
        for i, (req, s) in enumerate(zip(plan.requests, slots)):
            self.slot_out[s] = [int(first[i])]
            self.slot_last[s] = int(first[i])
            self.slot_temp[s] = req.temperature
            budget = req.max_new_tokens - 1
            if budget <= 0:
                self._completed.append(Result(req.rid, self.slot_out[s]))
                self.slot_free[s] = True
                self.slot_req[s] = None
                self.slot_budget[s] = 0
            else:
                self.slot_free[s] = False
                self.slot_req[s] = req
                self.slot_budget[s] = budget

    def _admit(self, pending: Deque[Request]):
        while pending:
            free = [s for s in range(self.slots) if self.slot_free[s]]
            if not free:
                break
            width = len(free) if self.batch_prefill else 1
            plan = self.scheduler.plan(pending, width)
            if plan is None:
                break
            self._prefill_into(plan, free[:len(plan.requests)])

    # ------------------------------------------------------------- decode --
    def _decode_block(self, n: int) -> List[Result]:
        """Run n decode steps on-device (one host sync), then retire
        finished slots."""
        live = [s for s in range(self.slots) if not self.slot_free[s]]
        if not live:
            return []
        active = np.asarray([not f for f in self.slot_free], bool)
        (self.caches, tok, _, budget, self.key, toks, emit) = \
            self._c.scan(n, self.slots)(
                self.params, self.caches, jnp.asarray(self.slot_last),
                jnp.asarray(active), jnp.asarray(self.slot_budget),
                jnp.asarray(self.slot_temp), self.key)
        toks, emit = np.asarray(toks), np.asarray(emit)
        self.slot_last = np.array(tok, np.int32)      # writable host mirrors
        self.slot_budget = np.array(budget, np.int32)
        done: List[Result] = []
        for s in live:
            self.slot_out[s].extend(int(t) for t in toks[emit[:, s], s])
            if self.slot_budget[s] <= 0:
                done.append(Result(self.slot_req[s].rid, self.slot_out[s]))
                self.slot_free[s] = True
                self.slot_req[s] = None
        return done

    def step(self) -> List[Result]:
        """One decode step for every live slot (the per-token-sync path)."""
        return self._decode_block(1)

    def _block_len(self) -> int:
        """Largest block that can't overshoot any live slot: stop at the
        earliest completion so slots free (and refill) at block boundaries
        and the RNG stream is identical for every scan_steps setting.

        Deliberate tradeoff: a short-budget request drags the whole batch
        to short blocks until it retires, and each distinct n compiles its
        own scan (bounded by scan_steps programs per model). Bucketing n
        would cut compiles but break the scan==stepwise token-for-token
        guarantee test_serving pins down; revisit if serving mixes budgets
        at scale."""
        live_budgets = [int(self.slot_budget[s]) for s in range(self.slots)
                        if not self.slot_free[s]]
        if not live_budgets:
            return 0
        return max(1, min(self.scan_steps, min(live_budgets)))

    # --------------------------------------------------------------- run ---
    def run(self, requests: List[Request]) -> List[Result]:
        pending: Deque[Request] = collections.deque(requests)
        results: List[Result] = []
        while pending or not all(self.slot_free):
            self._admit(pending)
            results.extend(self._completed)
            self._completed = []
            n = self._block_len()
            if n:
                results.extend(self._decode_block(n))
        return sorted(results, key=lambda r: r.rid)


def ring_cache_bytes(cfg: ModelConfig, batch: int, context: int) -> int:
    """Decode-cache bytes — the paper's Fig. 3 memory comparison. Window
    attention: O(window); dense: O(context). Counts PHYSICAL rows
    (`cache_allocation`: logical capacity + the tile-rounding tail)."""
    from repro.core.layers import cache_allocation
    from repro.core.model import attn_cfg
    total = 0
    for kind in cfg.layer_pattern:
        if kind.startswith("mamba"):
            spec = cfg.ssm
            h = spec.num_heads(cfg.d_model)
            total += batch * (h * spec.head_dim * spec.state_dim * 4
                              + (spec.conv_width - 1)
                              * (spec.d_inner(cfg.d_model)
                                 + 2 * spec.num_groups * spec.state_dim) * 2)
            continue
        acfg = attn_cfg(cfg, kind)
        cap = cache_allocation(acfg, context)
        total += 2 * batch * acfg.num_kv_heads * cap * acfg.head_dim * 2
    return total * cfg.num_super_blocks
