"""Device-resident continuous-batching serving engine.

The paper's FIFO K/V buffer is the serving-side win of window attention:
decode memory is O(window), not O(context) — SWAT's Fig. 3 linear-memory
claim. The engine turns that into throughput:

  * static batch of slots (TPU-friendly: shapes never change) with PER-SLOT
    ring write positions — every row of every cache tracks its own step, so
    slots at different depths share one batched kernel call,
  * batched, padded prefill: the scheduler packs all pending prompts that
    fit into one call (per-row `lengths` mask the padding), optionally
    chunked along the sequence axis so prefill VMEM is bounded by the chunk
    size rather than the longest prompt,
  * scan decode: N tokens per dispatch under `jax.lax.scan` with per-slot
    done/budget flags — the host syncs once per block instead of once per
    token (the seed engine's per-token round-trip),
  * per-slot temperature / top-k sampling (jitted; greedy rows take argmax).

Determinism: the RNG key splits once per executed decode step and once per
prefill batch, in the same order whatever `scan_steps` is (blocks stop at
the earliest slot completion), so scan decode is token-for-token identical
to stepwise decode — the property test_serving.py pins down.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as Mod
from repro.core.types import ModelConfig
from repro.serving import sampling
from repro.serving.scheduler import PrefillPlan, Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class _Compiled:
    """Jitted functions shared by every engine over the same
    (cfg, max_len, decode_impl, top_k): compiles are per-model, engines are
    cheap per-session objects (constructing a second engine must not pay
    XLA again — `_get_compiled` memoizes these)."""

    def __init__(self, cfg: ModelConfig, max_len: int, decode_impl: str,
                 top_k: int):
        self.cfg, self.max_len = cfg, max_len
        self.decode_impl, self.top_k = decode_impl, top_k
        self.prefill = jax.jit(lambda p, tok, lens: Mod.prefill(
            p, cfg, {"tokens": tok}, max_len=max_len, lengths=lens))
        self.chunk = jax.jit(self._chunk_impl)
        self.insert = jax.jit(lambda full, one, idx: jax.tree.map(
            lambda f, o: f.at[:, idx].set(o.astype(f.dtype)), full, one))
        self.sample = jax.jit(functools.partial(sampling.sample, top_k=top_k))
        self._scan_fns: Dict[int, Any] = {}
        self._init_fns: Dict[int, Any] = {}

    def _chunk_impl(self, params, caches, tok, pos0, lengths, last_logits):
        """One prefill chunk + carry of each row's last-real-token logits
        (pos0 is traced: one compile serves every chunk index). Only the
        gathered (B, 1, D) row is unembedded — never the whole chunk."""
        x, caches = Mod.prefill_chunk(
            params, self.cfg, {"tokens": tok}, caches, pos0, lengths)
        t = tok.shape[1]
        tpos = lengths - 1 - pos0
        hit = (tpos >= 0) & (tpos < t)
        xsel = jnp.take_along_axis(
            x, jnp.broadcast_to(
                jnp.clip(tpos, 0, t - 1)[:, None, None],
                (x.shape[0], 1, x.shape[2])), axis=1)
        sel = Mod._unembed(params, self.cfg, xsel)[:, 0]
        return jnp.where(hit[:, None], sel, last_logits), caches

    def fresh_caches(self, n: int):
        if n not in self._init_fns:
            self._init_fns[n] = jax.jit(
                lambda: Mod.init_caches(self.cfg, n, self.max_len))
        return self._init_fns[n]()

    def scan(self, n: int):
        if n not in self._scan_fns:
            self._scan_fns[n] = self._make_scan(n)
        return self._scan_fns[n]

    def _make_scan(self, n: int):
        cfg, impl, top_k = self.cfg, self.decode_impl, self.top_k

        def fn(params, caches, tok, active, budget, temps, key):
            def body(carry, _):
                caches, tok, active, budget, key = carry
                logits, caches = Mod.decode_step(
                    params, cfg, {"tokens": tok[:, None]}, caches, impl=impl)
                key, sub = jax.random.split(key)
                nxt = sampling.sample(sub, logits[:, 0], temps, top_k)
                nxt = jnp.where(active, nxt, tok)
                emitted = active
                budget = budget - active.astype(jnp.int32)
                active = active & (budget > 0)
                return (caches, nxt, active, budget, key), (nxt, emitted)

            carry, (toks, emit) = jax.lax.scan(
                body, (caches, tok, active, budget, key), None, length=n)
            caches, tok, active, budget, key = carry
            return caches, tok, active, budget, key, toks, emit

        return jax.jit(fn)


@functools.lru_cache(maxsize=16)
def _get_compiled(cfg: ModelConfig, max_len: int, decode_impl: str,
                  top_k: int) -> _Compiled:
    return _Compiled(cfg, max_len, decode_impl, top_k)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 8,
                 max_len: int = 4096, seed: int = 0, scan_steps: int = 8,
                 batch_prefill: bool = True, prefill_chunk: int = 0,
                 max_prefill_tokens: int = 8192, pad_to: int = 16,
                 top_k: int = 0, decode_impl: str = "ref"):
        """scan_steps=1 degenerates to the seed engine's per-token host
        sync; prefill_chunk=0 disables sequence-axis chunking (single-shot
        batched prefill); batch_prefill=False admits one prompt per prefill
        call (the seed behavior, kept for benchmarking)."""
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.scan_steps = max(1, scan_steps)
        self.batch_prefill = batch_prefill
        self.prefill_chunk = (prefill_chunk
                              if Mod.prefill_chunkable(cfg) else 0)
        self.top_k = top_k
        self.decode_impl = decode_impl
        self.key = jax.random.PRNGKey(seed)
        self.scheduler = Scheduler(max_prefill_tokens=max_prefill_tokens,
                                   pad_to=pad_to)

        self.caches = Mod.init_caches(cfg, batch_slots, max_len)
        self.slot_free = [True] * batch_slots
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_last = np.zeros((batch_slots,), np.int32)
        self.slot_budget = np.zeros((batch_slots,), np.int32)
        self.slot_temp = np.zeros((batch_slots,), np.float32)
        self._completed: List[Result] = []
        self._c = _get_compiled(cfg, max_len, decode_impl, top_k)

    # ------------------------------------------------------------ prefill --
    def _prefill_into(self, plan: PrefillPlan, slots: List[int]):
        n, l_pad = plan.tokens.shape
        tokens = jnp.asarray(plan.tokens)
        lengths = jnp.asarray(plan.lengths)
        if self.prefill_chunk and l_pad > self.prefill_chunk:
            caches = self._c.fresh_caches(n)
            last = jnp.zeros((n, self.cfg.vocab_size), jnp.float32)
            for p in range(0, l_pad, self.prefill_chunk):
                chunk = tokens[:, p:p + self.prefill_chunk]
                last, caches = self._c.chunk(
                    self.params, caches, chunk, jnp.int32(p), lengths, last)
            logits = last
        else:
            out, caches = self._c.prefill(self.params, tokens, lengths)
            logits = out[:, 0]
        temps = np.asarray([r.temperature for r in plan.requests], np.float32)
        self.key, sub = jax.random.split(self.key)
        first = np.asarray(self._c.sample(sub, logits, jnp.asarray(temps)))
        self.caches = self._c.insert(self.caches, caches,
                                     jnp.asarray(slots, jnp.int32))
        for i, (req, s) in enumerate(zip(plan.requests, slots)):
            self.slot_out[s] = [int(first[i])]
            self.slot_last[s] = int(first[i])
            self.slot_temp[s] = req.temperature
            budget = req.max_new_tokens - 1
            if budget <= 0:
                self._completed.append(Result(req.rid, self.slot_out[s]))
                self.slot_free[s] = True
                self.slot_req[s] = None
                self.slot_budget[s] = 0
            else:
                self.slot_free[s] = False
                self.slot_req[s] = req
                self.slot_budget[s] = budget

    def _admit(self, pending: Deque[Request]):
        while pending:
            free = [s for s in range(self.slots) if self.slot_free[s]]
            if not free:
                break
            width = len(free) if self.batch_prefill else 1
            plan = self.scheduler.plan(pending, width)
            if plan is None:
                break
            self._prefill_into(plan, free[:len(plan.requests)])

    # ------------------------------------------------------------- decode --
    def _decode_block(self, n: int) -> List[Result]:
        """Run n decode steps on-device (one host sync), then retire
        finished slots."""
        live = [s for s in range(self.slots) if not self.slot_free[s]]
        if not live:
            return []
        active = np.asarray([not f for f in self.slot_free], bool)
        (self.caches, tok, _, budget, self.key, toks, emit) = \
            self._c.scan(n)(
                self.params, self.caches, jnp.asarray(self.slot_last),
                jnp.asarray(active), jnp.asarray(self.slot_budget),
                jnp.asarray(self.slot_temp), self.key)
        toks, emit = np.asarray(toks), np.asarray(emit)
        self.slot_last = np.array(tok, np.int32)      # writable host mirrors
        self.slot_budget = np.array(budget, np.int32)
        done: List[Result] = []
        for s in live:
            self.slot_out[s].extend(int(t) for t in toks[emit[:, s], s])
            if self.slot_budget[s] <= 0:
                done.append(Result(self.slot_req[s].rid, self.slot_out[s]))
                self.slot_free[s] = True
                self.slot_req[s] = None
        return done

    def step(self) -> List[Result]:
        """One decode step for every live slot (the per-token-sync path)."""
        return self._decode_block(1)

    def _block_len(self) -> int:
        """Largest block that can't overshoot any live slot: stop at the
        earliest completion so slots free (and refill) at block boundaries
        and the RNG stream is identical for every scan_steps setting.

        Deliberate tradeoff: a short-budget request drags the whole batch
        to short blocks until it retires, and each distinct n compiles its
        own scan (bounded by scan_steps programs per model). Bucketing n
        would cut compiles but break the scan==stepwise token-for-token
        guarantee test_serving pins down; revisit if serving mixes budgets
        at scale."""
        live_budgets = [int(self.slot_budget[s]) for s in range(self.slots)
                        if not self.slot_free[s]]
        if not live_budgets:
            return 0
        return max(1, min(self.scan_steps, min(live_budgets)))

    # --------------------------------------------------------------- run ---
    def run(self, requests: List[Request]) -> List[Result]:
        pending: Deque[Request] = collections.deque(requests)
        results: List[Result] = []
        while pending or not all(self.slot_free):
            self._admit(pending)
            results.extend(self._completed)
            self._completed = []
            n = self._block_len()
            if n:
                results.extend(self._decode_block(n))
        return sorted(results, key=lambda r: r.rid)


def ring_cache_bytes(cfg: ModelConfig, batch: int, context: int) -> int:
    """Decode-cache bytes — the paper's Fig. 3 memory comparison. Window
    attention: O(window); dense: O(context)."""
    from repro.core.layers import cache_capacity
    from repro.core.model import attn_cfg
    total = 0
    for kind in cfg.layer_pattern:
        if kind.startswith("mamba"):
            spec = cfg.ssm
            h = spec.num_heads(cfg.d_model)
            total += batch * (h * spec.head_dim * spec.state_dim * 4
                              + (spec.conv_width - 1)
                              * (spec.d_inner(cfg.d_model)
                                 + 2 * spec.num_groups * spec.state_dim) * 2)
            continue
        acfg = attn_cfg(cfg, kind)
        cap = cache_capacity(acfg, context)
        total += 2 * batch * acfg.num_kv_heads * cap * acfg.head_dim * 2
    return total * cfg.num_super_blocks
