"""Draft proposers for speculative decoding.

Speculative decode splits every engine step into (draft, verify, accept):
a cheap proposer guesses the next `k` tokens, the full model verifies all
k+1 positions in ONE `decode_step` dispatch (the PR-3 multi-query
primitive: each draft token gets its own causal/window slice of the
lookahead ring), and the engine keeps the longest prefix of drafts that
match what the model itself would have emitted. Acceptance only changes
*speed* — every emitted token is exactly the model's own output for its
(verified) prefix, so greedy speculative decode is token-for-token the
sequential engine (tests/test_speculative.py pins this down end-to-end).

The proposer here is the zero-extra-model option: **n-gram self-drafting**
(prompt-lookup decoding). Each slot carries a small rolling history of its
own tokens (prompt + everything emitted); to draft, we find the most
recent — longest-suffix-match — earlier occurrence of the current context
and propose the tokens that followed it. Window-attention serving is a
particularly good fit: SWA-trained models hold quality at long context by
leaning on local structure, and local structure (templated output, code,
retrieval-stuffed prompts, greedy decode's own loops) is exactly what an
n-gram matcher predicts well. The interface is deliberately small and the
spec is a frozen dataclass (it is part of the engine's compile key), so a
learned small-model drafter can slot in later without touching the engine
loop.

Everything here is device-resident and shape-static: `propose` and
`observe` are called inside the engine's jitted decode body (no host
round trips), state is a right-aligned (slots, history) ring the engine
threads like any other per-slot decode state (it shards over the slot
axis under a mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NGramDrafter:
    """Self-drafting n-gram proposer (prompt-lookup decoding).

    max_ngram: longest context suffix to match (longer matches win; ties go
        to the most recent occurrence).
    history:  per-slot token history kept on device, newest token at the
        END of the buffer (right-aligned — suffix extraction is static).

    Frozen/hashable on purpose: the drafter spec is part of the engine's
    compile identity (`_get_compiled`), like `tokens_per_step`.
    """
    max_ngram: int = 3
    history: int = 64

    # ------------------------------------------------------------- state --
    def init_state(self, slots: int) -> Tuple[np.ndarray, np.ndarray]:
        """(hist (slots, H) int32, count (slots,) int32) host mirrors —
        the engine owns them exactly like slot_last/slot_budget."""
        return (np.zeros((slots, self.history), np.int32),
                np.zeros((slots,), np.int32))

    def seed_row(self, tokens: np.ndarray) -> Tuple[np.ndarray, np.int32]:
        """History row for a freshly admitted slot: the prompt plus the
        prefill-sampled first token, right-aligned into the buffer."""
        h = self.history
        seq = np.asarray(tokens, np.int32).reshape(-1)[-h:]
        row = np.zeros((h,), np.int32)
        if seq.size:
            row[h - seq.size:] = seq
        return row, np.int32(seq.size)

    # ------------------------------------------------------------ propose --
    def propose(self, hist, count, k: int):
        """Draft k tokens per slot. hist: (B, H) right-aligned (newest at
        H-1 — the slot's current last token); count: (B,) valid entries.

        For every candidate match end p (an earlier history position), the
        match score is the longest n <= max_ngram with
        hist[p-n+1 .. p] == hist[H-n .. H-1] (the current context suffix).
        The winner is the longest match, most recent on ties; drafts are
        the tokens that followed it. Slots with no match propose their last
        token repeated — any proposal is *correct* (verification gates
        emission), a bad one just wastes the lookahead."""
        b, h = hist.shape
        idx = jnp.arange(h, dtype=jnp.int32)[None, :]          # (1, H)
        count = jnp.asarray(count, jnp.int32)
        first = h - jnp.minimum(count, h)[:, None]             # (B, 1)
        score = jnp.zeros((b, h), jnp.int32)
        for n in range(1, self.max_ngram + 1):
            m = count[:, None] >= n + 1   # suffix of n + >=1 token before it
            for i in range(n):
                src = idx - (n - 1) + i
                tok = jnp.take_along_axis(
                    hist, jnp.clip(src, 0, h - 1), axis=1)     # (B, H)
                suf = hist[:, h - n + i][:, None]              # (B, 1)
                m = m & (tok == suf) & (src >= first)
            score = jnp.where(m, n, score)
        # a candidate needs a continuation: strictly before the newest token
        usable = (idx <= h - 2) & (idx >= first)
        score = jnp.where(usable, score, 0)
        rank = score * h + idx                   # longer match, then recency
        best = jnp.argmax(rank, axis=1)                        # (B,)
        has = jnp.take_along_axis(score, best[:, None], 1)[:, 0] > 0
        gather = jnp.clip(best[:, None] + 1 + jnp.arange(k)[None, :],
                          0, h - 1)
        drafts = jnp.take_along_axis(hist, gather, axis=1)     # (B, k)
        last = hist[:, h - 1][:, None]
        return jnp.where(has[:, None], drafts, last)

    # ----------------------------------------------------------- sanitize --
    @staticmethod
    def sanitize(drafts, vocab_size: int):
        """Clip drafts into [0, vocab). Proposals are *suggestions* — a
        corrupted or buggy drafter must never crash the verify step or,
        worse, exploit jax's out-of-bounds gather semantics (indices clamp
        silently under jit) to smuggle a plausible-but-wrong embedding row
        into the model. Clipped garbage simply fails verification: the
        engine emits the model's own token and drops the drafts — the
        fault-injection suite drives this with out-of-vocab proposals."""
        return jnp.clip(jnp.asarray(drafts, jnp.int32), 0, vocab_size - 1)

    # ------------------------------------------------------------ observe --
    def observe(self, hist, count, tokens, num_emitted):
        """Append each slot's first `num_emitted` of `tokens` (B, T) to its
        history (ragged per slot; num_emitted=0 rows are untouched).
        Right-aligned shift via one gather — no per-row branches."""
        b, h = hist.shape
        e = jnp.asarray(num_emitted, jnp.int32)
        buf = jnp.concatenate([hist, jnp.asarray(tokens, hist.dtype)], axis=1)
        gather = e[:, None] + jnp.arange(h, dtype=jnp.int32)[None, :]
        return (jnp.take_along_axis(buf, gather, axis=1),
                jnp.minimum(count + e, h))


def get_drafter(spec) -> NGramDrafter:
    """Normalize the engine's `draft=` knob: None -> default NGramDrafter,
    a drafter instance passes through. The seam where a small-model drafter
    config would be resolved later."""
    if spec is None:
        return NGramDrafter()
    assert isinstance(spec, NGramDrafter), spec
    return spec
