"""qwen2.5-32b [dense] — GQA with QKV bias.
64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064. [hf:Qwen/Qwen2.5]
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    num_layers=64,
    d_model=5120,
    num_heads=40, num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    layer_pattern=("attn",),
    attention=AttentionSpec(kind="dense", causal=True),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
)
