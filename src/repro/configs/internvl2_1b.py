"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B-style LM backbone.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. [arXiv:2404.16821]

Backbone only per assignment: the ViT patch tower is a STUB — input_specs
feeds precomputed patch(+text) embeddings for train/prefill; decode embeds
text tokens normally.
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    num_layers=24,
    d_model=896,
    num_heads=14, num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    layer_pattern=("attn",),
    attention=AttentionSpec(kind="dense", causal=True),
    qkv_bias=True,                       # qwen2 family uses QKV bias
    frontend="vision",                   # patch-embedding stub at train/prefill
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    norm_eps=1e-6,
)
