"""The paper's BigBird configuration (Table 2): 192 sliding-window tokens,
192 random tokens, 128 global tokens = 512 attended tokens per row.

Random attention is block-granular in both BigBird and SWAT (whole K/V
buffers assigned to random cores); with block_kv=128 we use 2 random blocks
(~256 tokens, the closest block multiple to 192 — noted approximation).
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="bigbird-paper",
    num_layers=12,
    d_model=768,
    num_heads=12, num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50358,
    layer_pattern=("attn",),
    attention=AttentionSpec(kind="swat", window=96, num_global=128,
                            num_random=2, random_seed=2024, causal=False),
    norm_eps=1e-5,
)
