"""granite-8b [dense] — llama-architecture code model.
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152. [arXiv:2405.04324]
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    num_layers=36,
    d_model=4096,
    num_heads=32, num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    layer_pattern=("attn",),
    attention=AttentionSpec(kind="dense", causal=True),
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    norm_eps=1e-5,
)
