"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000. [arXiv:2408.00118]

The native fit for the paper: the 13 local layers ARE sliding-window
attention (w=4096) and use the SWAT kernel in the faithful config.
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    num_layers=26,
    d_model=2304,
    num_heads=8, num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local_attn", "attn"),
    local_attention=AttentionSpec(kind="swat", window=4096, causal=True,
                                  softcap=50.0),
    attention=AttentionSpec(kind="dense", causal=True, softcap=50.0),
    final_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    norm_eps=1e-6,
)
