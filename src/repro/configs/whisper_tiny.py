"""whisper-tiny [audio] — encoder-decoder with conv frontend STUB.
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. [arXiv:2212.04356]

input_specs feeds precomputed frame embeddings (the conv1+conv2 frontend is
the assignment-mandated stub); encoder is bidirectional self-attention,
decoder is causal self + cross attention. Structural decoder limit 448 —
decode_32k is lowered mechanically on the backbone; long_500k skipped
(DESIGN.md §4).
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    num_layers=4,                       # decoder layers
    encoder_layers=4,
    encoder_decoder=True,
    d_model=384,
    num_heads=6, num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    layer_pattern=("xattn",),
    attention=AttentionSpec(kind="dense", causal=True),
    use_rope=False,                     # sinusoidal absolute positions
    frontend="audio",
    max_decode_len=448,
    norm_eps=1e-5,
)

ENCODER_FRAMES = 1500                   # 30 s of audio after conv frontend
