"""granite-moe-1b-a400m [moe] — 32 experts top-8.
24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.core.types import AttentionSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    num_layers=24,
    d_model=1024,
    num_heads=16, num_kv_heads=8,
    head_dim=64,
    d_ff=512,                           # per-expert FFN width
    vocab_size=49155,
    layer_pattern=("attn_moe",),
    attention=AttentionSpec(kind="dense", causal=True),
    moe=MoESpec(num_experts=32, top_k=8),
    tie_embeddings=True,
    norm_eps=1e-6,
)
