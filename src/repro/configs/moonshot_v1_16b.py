"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.core.types import AttentionSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    num_layers=48,
    d_model=2048,
    num_heads=16, num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                          # per-expert FFN width
    vocab_size=163840,
    layer_pattern=("attn_moe",),
    attention=AttentionSpec(kind="dense", causal=True),
    moe=MoESpec(num_experts=64, top_k=6),
    rope_theta=50_000.0,
    norm_eps=1e-5,
)
