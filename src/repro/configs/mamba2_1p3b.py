"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128. [arXiv:2405.21060]

SWAT applicability: none (no QK^T) — see DESIGN.md §4. long_500k runs via
the O(1) recurrent state (the SSM counterpart of the ring cache).
"""
from repro.core.types import AttentionSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    num_layers=48,
    d_model=2048,
    num_heads=1, num_kv_heads=1,        # unused: attention-free
    d_ff=0,                             # mamba2 blocks have no separate FFN
    vocab_size=50280,
    layer_pattern=("mamba",),
    attention=AttentionSpec(kind="dense", causal=True),   # unused
    ssm=SSMSpec(state_dim=128, head_dim=64, expand=2, conv_width=4,
                chunk_size=256, num_groups=1),
    tie_embeddings=True,
    norm_eps=1e-5,
)
