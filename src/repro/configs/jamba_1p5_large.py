"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887]

Period-8 super-block: 7 mamba + 1 attention (position 4, as in the Jamba
paper), MoE on every other layer => 4 MoE layers per super-block.
~398B total / ~98B active parameters.
"""
from repro.core.types import AttentionSpec, ModelConfig, MoESpec, SSMSpec

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    num_layers=72,
    d_model=8192,
    num_heads=64, num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
                   "attn", "mamba_moe", "mamba", "mamba_moe"),
    attention=AttentionSpec(kind="dense", causal=True),
    moe=MoESpec(num_experts=16, top_k=2),
    ssm=SSMSpec(state_dim=128, head_dim=64, expand=2, conv_width=4,
                chunk_size=256, num_groups=1),
    norm_eps=1e-5,
)
