"""The paper's own primary model: Longformer (window attention, 2w=512).

SWAT §4: "standard window width configuration (2w = 512), 512 attention
cores", head dim H=64. Longformer-base backbone: 12L d_model=768 12H
d_ff=3072. Bidirectional (LRA-style encoder) with 1 global CLS token.
"""
from repro.core.types import AttentionSpec, ModelConfig

CONFIG = ModelConfig(
    name="longformer-paper",
    num_layers=12,
    d_model=768,
    num_heads=12, num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50265,
    layer_pattern=("attn",),
    attention=AttentionSpec(kind="swat", window=256, num_global=1,
                            causal=False),
    norm_eps=1e-5,
)
