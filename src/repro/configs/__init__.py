"""Architecture registry: one module per assigned arch (+ the paper's own
Longformer/BigBird models). `get_config(name)` returns the full-size
ModelConfig; `get_smoke_config(name)` a structurally-identical reduced one
for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.core.types import AttentionSpec, ModelConfig, MoESpec, SSMSpec

ARCH_IDS = (
    "mamba2_1p3b",
    "internvl2_1b",
    "llama3p2_1b",
    "qwen2p5_32b",
    "granite_8b",
    "gemma2_2b",
    "whisper_tiny",
    "jamba_1p5_large",
    "granite_moe_1b",
    "moonshot_v1_16b",
)
PAPER_IDS = ("longformer_paper", "bigbird_paper")

_ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "internvl2-1b": "internvl2_1b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen2.5-32b": "qwen2p5_32b",
    "granite-8b": "granite_8b",
    "gemma2-2b": "gemma2_2b",
    "whisper-tiny": "whisper_tiny",
    "jamba-1.5-large-398b": "jamba_1p5_large",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return reduce_config(get_config(name))


def with_swat(cfg: ModelConfig, window: int = 2048,
              num_global: int = 128) -> ModelConfig:
    """Beyond-paper variant: swap every dense attention layer for SWAT
    window(+sink) attention — the paper's pitch applied to a modern LM.
    No-op for attention-free archs."""
    if cfg.is_attention_free:
        return cfg
    new_attn = dataclasses.replace(
        cfg.attention, kind="swat", window=window, num_global=num_global)
    return dataclasses.replace(cfg, name=cfg.name + "+swat",
                               attention=new_attn)


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink every capacity knob while preserving structure (pattern, GQA
    ratio, MoE/SSM/bias/softcap flags)."""
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = min(cfg.num_heads, 4)
    kv = max(1, heads // ratio)
    moe = (MoESpec(num_experts=min(cfg.moe.num_experts, 4),
                   top_k=min(cfg.moe.top_k, 2))
           if cfg.moe.enabled else MoESpec())
    ssm = dataclasses.replace(cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16),
                              head_dim=min(cfg.ssm.head_dim, 16),
                              chunk_size=16)

    def shrink_spec(spec: Optional[AttentionSpec]):
        if spec is None:
            return None
        return dataclasses.replace(
            spec, window=min(spec.window, 16) if spec.window else 0,
            num_global=min(spec.num_global, 4),
            num_random=min(spec.num_random, 1))

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=2 * len(cfg.layer_pattern),
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=min(cfg.d_ff, 128),
        vocab_size=min(cfg.vocab_size, 256),
        attention=shrink_spec(cfg.attention),
        local_attention=shrink_spec(cfg.local_attention),
        moe=moe,
        ssm=ssm,
        encoder_layers=min(cfg.encoder_layers, 2),
        dtype="float32",
    )
