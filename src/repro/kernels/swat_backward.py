"""SWAT backward kernels: banded flash-attention gradients.

Two kernels, both driven by the same trace-time block pattern as the forward:
  dQ    - grid (B, Hq, q_block, slot): same schedule as forward.
  dK/dV - grid (B, Hq, kv_block, inv_slot): the *inverse* pattern (per kv
          block, the q blocks that touch it) — pure-numpy inversion, see
          patterns.BlockPattern.inverse().

GQA: dK/dV are produced per q-head and group-summed outside (keeps every
output block visited by exactly one grid step, so no cross-step races).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import patterns
from repro.core.types import AttentionSpec

from repro.kernels.swat_attention import LANES, NEG_INF


def _scores(q, k, scale, softcap):
    """Recompute the (capped) score block in fp32. Returns (s, ds_chain)
    where ds_chain is the d(capped)/d(raw) factor (None when no cap)."""
    s = jax.lax.dot_general(q.astype(jnp.float32) * scale,
                            k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap:
        t = jnp.tanh(s / softcap)
        return softcap * t, (1.0 - t * t)
    return s, None


def _block_mask(spec, i, j, block_q, block_kv, seq_kv, kind,
                q_offset=0, kv_offset=0):
    q_idx = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_idx = kv_offset + j * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    from repro.kernels.swat_attention import element_mask
    return element_mask(spec, q_idx, k_idx, seq_kv, kind)


def _dq_kernel(kv_map_ref, kinds_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, dq_acc,
               *, spec, block_q, block_kv, seq_kv, num_slots, scale,
               q_offset=0, kv_offset=0):
    i = pl.program_id(2)
    s = pl.program_id(3)
    kind = kinds_ref[i, s]
    j = kv_map_ref[i, s]

    @pl.when(s == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when(kind != patterns.PAD)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0].astype(jnp.float32)
        st, chain = _scores(q, k, scale, spec.softcap)
        mask = _block_mask(spec, i, j, block_q, block_kv, seq_kv, kind,
                           q_offset, kv_offset)
        st = jnp.where(mask, st, NEG_INF)
        lse = lse_ref[0, 0][:, :1]                       # (BQ, 1)
        p = jnp.exp(st - lse)                            # (BQ, BK)
        do = do_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        delta = delta_ref[0, 0][:, :1]                   # (BQ, 1)
        ds = p * (dp - delta)
        if chain is not None:
            ds = ds * chain
        ds = jnp.where(mask, ds, 0.0)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(s == num_slots - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_map_ref, kinds_ref,
                q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, spec, block_q, block_kv, seq_kv, num_slots, scale,
                q_offset=0, kv_offset=0):
    j = pl.program_id(2)   # kv block
    s = pl.program_id(3)   # q slot
    kind = kinds_ref[j, s]
    i = q_map_ref[j, s]

    @pl.when(s == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(kind != patterns.PAD)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0].astype(jnp.float32)
        st, chain = _scores(q, k, scale, spec.softcap)    # (BQ, BK)
        mask = _block_mask(spec, i, j, block_q, block_kv, seq_kv, kind,
                           q_offset, kv_offset)
        st = jnp.where(mask, st, NEG_INF)
        lse = lse_ref[0, 0][:, :1]
        p = jnp.exp(st - lse)                             # (BQ, BK)
        do = do_ref[0, 0].astype(jnp.float32)             # (BQ, D)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        delta = delta_ref[0, 0][:, :1]
        ds = p * (dp - delta)
        if chain is not None:
            ds = ds * chain
        ds = jnp.where(mask, ds, 0.0)
        dk_acc[...] += jax.lax.dot_general(
            ds, q.astype(jnp.float32),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BK, D)

    @pl.when(s == num_slots - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def swat_attention_bwd(q, k, v, o, lse, do, spec: AttentionSpec, *,
                       pattern: patterns.BlockPattern,
                       scale: Optional[float] = None,
                       interpret: bool = False,
                       q_offset: int = 0, kv_offset: int = 0,
                       seq_kv_bound: Optional[int] = None):
    """Returns (dq, dk, dv). q/do: (B,Hq,Lq,D); k/v: (B,Hkv,Lkv,D);
    lse: (B,Hq,Lq) fp32. Offsets: global coordinates (context parallelism),
    matching the forward call."""
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    scale = float(d ** -0.5 if scale is None else scale)
    if seq_kv_bound is None:
        seq_kv_bound = kv_offset + lkv
    block_q, block_kv = pattern.block_q, pattern.block_kv
    nq, num_slots = pattern.num_q_blocks, pattern.num_slots
    nkv = pattern.num_kv_blocks

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    lq_pad, lkv_pad = nq * block_q, nkv * block_kv
    if lq_pad != lq:
        pad4 = ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0))
        pad3 = ((0, 0), (0, 0), (0, lq_pad - lq))
        q, do = jnp.pad(q, pad4), jnp.pad(do, pad4)
        lse = jnp.pad(lse, pad3, constant_values=0.0)
        delta = jnp.pad(delta, pad3)
    if lkv_pad != lkv:
        pad = ((0, 0), (0, 0), (0, lkv_pad - lkv), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)

    # (B,H,L) rows -> (B,H,L,LANES) so lse/delta blocks are 2D VMEM tiles
    lse_t = jnp.broadcast_to(lse[..., None], lse.shape + (LANES,))
    delta_t = jnp.broadcast_to(delta[..., None], delta.shape + (LANES,))

    kwargs = dict(spec=spec, block_q=block_q, block_kv=block_kv,
                  seq_kv=seq_kv_bound, scale=scale,
                  q_offset=q_offset, kv_offset=kv_offset)

    # ---- dQ ----
    kv_map = jnp.asarray(pattern.kv_block_map)
    kinds = jnp.asarray(pattern.slot_kinds)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda bb, h, i, s, bm, km: (bb, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, block_kv, d),
                           lambda bb, h, i, s, bm, km: (bb, h // group,
                                                        bm[i, s], 0))
    row_spec = pl.BlockSpec((1, 1, block_q, LANES),
                            lambda bb, h, i, s, bm, km: (bb, h, i, 0))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_slots=num_slots, **kwargs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hq, nq, num_slots),
            in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
            out_specs=[q_spec],
            scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((b, hq, lq_pad, d), q.dtype)],
        interpret=interpret, name="swat_attention_dq",
    )(kv_map, kinds, q, k, v, do, lse_t, delta_t)[0]

    # ---- dK/dV (inverse pattern; per q-head, group-summed after) ----
    inv = pattern.inverse()
    ninv = inv.num_slots
    q_map = jnp.asarray(inv.q_block_map)
    ikinds = jnp.asarray(inv.slot_kinds)
    iq_spec = pl.BlockSpec((1, 1, block_q, d),
                           lambda bb, h, j, s, qm, km: (bb, h, qm[j, s], 0))
    ikv_spec = pl.BlockSpec((1, 1, block_kv, d),
                            lambda bb, h, j, s, qm, km: (bb, h // group, j, 0))
    okv_spec = pl.BlockSpec((1, 1, block_kv, d),
                            lambda bb, h, j, s, qm, km: (bb, h, j, 0))
    irow_spec = pl.BlockSpec((1, 1, block_q, LANES),
                             lambda bb, h, j, s, qm, km: (bb, h, qm[j, s], 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, num_slots=ninv, **kwargs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hq, nkv, ninv),
            in_specs=[iq_spec, ikv_spec, ikv_spec, iq_spec, irow_spec,
                      irow_spec],
            out_specs=[okv_spec, okv_spec],
            scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                            pltpu.VMEM((block_kv, d), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((b, hq, lkv_pad, d), k.dtype),
                   jax.ShapeDtypeStruct((b, hq, lkv_pad, d), v.dtype)],
        interpret=interpret, name="swat_attention_dkv",
    )(q_map, ikinds, q, k, v, do, lse_t, delta_t)

    dq = dq[:, :, :lq]
    dk, dv = dk[:, :, :lkv], dv[:, :, :lkv]
    if group > 1:  # GQA: sum q-head contributions within each kv group
        dk = dk.reshape(b, hkv, group, lkv, d).sum(axis=2)
        dv = dv.reshape(b, hkv, group, lkv, d).sum(axis=2)
    return dq, dk, dv
