"""Pure-jnp oracles for every kernel. O(N^2) — tests and small shapes only.

Three reference implementations matter to the paper:
  attention_ref       - the standard 3-step QK / softmax / SV computation
  fused_attention_ref - the paper's Eq. 1 rewrite (exp, SV, divide-at-end);
                        proving attention_ref == fused_attention_ref is the
                        paper's kernel-fusion correctness claim
  sliding_chunks_ref  - the HuggingFace Longformer baseline the paper beats
                        (dense 2w-wide chunks, ~50% redundant FLOPs)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns
from repro.core.types import AttentionSpec


def _soft_cap(s, cap: float):
    return cap * jnp.tanh(s / cap) if cap else s


def attention_ref(q, k, v, spec: AttentionSpec, *,
                  pattern: Optional[patterns.BlockPattern] = None,
                  scale: Optional[float] = None):
    """Masked softmax attention, standard 3-step form, fp32 math.

    q: (B, Hq, Lq, D), k/v: (B, Hkv, Lk, D). GQA by head repetition.
    The mask comes from the *pattern* when given (includes random blocks),
    else from the dense spec mask.
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if pattern is not None:
        mask = patterns.random_blocks_mask(pattern)
    else:
        mask = patterns.dense_mask(spec, lq, k.shape[2])
    mask = jnp.asarray(mask)[None, None]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _soft_cap(s, spec.softcap)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)  # rows with no valid kv produce 0, not NaN
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def fused_attention_ref(q, k, v, spec: AttentionSpec, *,
                        pattern: Optional[patterns.BlockPattern] = None,
                        scale: Optional[float] = None,
                        stabilize: bool = True):
    """Paper Eq. 1: Z_i = (1/sum_l exp(S_il)) * sum_n exp(S_in) V_n.

    With stabilize=False this is the paper's literal formulation (no max
    subtraction — overflows for large |S|, as on their FPGA it did not at
    fp16 scale). stabilize=True subtracts the row max first (our deviation,
    mathematically identical)."""
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if pattern is not None:
        mask = patterns.random_blocks_mask(pattern)
    else:
        mask = patterns.dense_mask(spec, lq, k.shape[2])
    mask = jnp.asarray(mask)[None, None]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _soft_cap(s, spec.softcap)
    s = jnp.where(mask, s, -jnp.inf)
    if stabilize:
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        e = jnp.where(mask, jnp.exp(s - m), 0.0)
    else:
        e = jnp.where(mask, jnp.exp(s), 0.0)
    num = jnp.einsum("bhqk,bhkd->bhqd", e, v.astype(jnp.float32))
    den = jnp.sum(e, axis=-1, keepdims=True)
    return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)


def sliding_chunks_ref(q, k, v, spec: AttentionSpec, *,
                       scale: Optional[float] = None):
    """HF Longformer 'sliding chunks': split the sequence into chunks of 2w,
    compute *dense* attention of every chunk against [itself, next chunk]
    (bidirectional also previous), mask to the band afterwards. The overlap
    regions are the paper's ~50% wasted FLOPs. Exact same output as
    attention_ref with the band mask; only the compute schedule differs.

    Requires seq divisible by 2w. num_global/num_random unsupported (as in
    HF's kernel — globals are a separate dense pass there too).
    """
    assert spec.kind in ("swat", "sliding_chunks")
    w = spec.window
    c = 2 * w
    b, h, l, d = q.shape
    assert l % c == 0, f"sliding_chunks needs seq % {c} == 0, got {l}"
    hkv = k.shape[1]
    if h != hkv:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    scale = scale if scale is not None else d ** -0.5
    n = l // c
    qc = q.reshape(b, h, n, c, d).astype(jnp.float32)
    kc = k.reshape(b, h, n, c, d).astype(jnp.float32)
    vc = v.reshape(b, h, n, c, d).astype(jnp.float32)

    def neigh(x, shift):  # chunk at offset `shift`, zero-padded at the ends
        pad = jnp.zeros_like(x[:, :, :1])
        if shift == -1:
            return jnp.concatenate([pad, x[:, :, :-1]], axis=2)
        if shift == 1:
            return jnp.concatenate([x[:, :, 1:], pad], axis=2)
        return x

    shifts = (-1, 0) if spec.causal else (-1, 0, 1)
    ks = jnp.concatenate([neigh(kc, s) for s in shifts], axis=3)
    vs = jnp.concatenate([neigh(vc, s) for s in shifts], axis=3)
    s_ = jnp.einsum("bhncd,bhnkd->bhnck", qc, ks) * scale
    s_ = _soft_cap(s_, spec.softcap)

    # band mask in chunk coordinates
    q_idx = np.arange(c)[:, None]
    k_off = np.concatenate([np.arange(c) + s * c for s in shifts])[None, :]
    band = (k_off >= q_idx - w) & ((k_off <= q_idx) if spec.causal
                                   else (k_off <= q_idx + w))
    valid = np.ones((len(shifts) * c,), bool)[None, :]
    mask = jnp.asarray(band & valid)[None, None, None]
    # first/last chunk: padded neighbours are invalid
    chunk_ids = jnp.arange(n)[:, None, None]
    pad_lo = (jnp.asarray(k_off) < 0)[None] & (chunk_ids == 0)
    pad_hi = (jnp.asarray(k_off) >= c)[None] & (chunk_ids == n - 1)
    mask = mask & ~pad_lo[None, None] & ~pad_hi[None, None]

    s_ = jnp.where(mask, s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhnck,bhnkd->bhncd", p, vs)
    return out.reshape(b, h, l, d).astype(q.dtype)


def ring_slot_positions(total, wcap: int, *, ring_cap: int, num_global: int):
    """Which absolute token index each cache slot holds, given per-slot
    `total` (B,) tokens inserted so far. Pinned slot s (< num_global) holds
    token s; ring slot r holds the newest token congruent to r below
    `total`. Returns (positions (B, W) int32, valid (B, W) bool); slots in
    the tile-rounding tail [ring_cap, W) are never valid."""
    g, ring = num_global, ring_cap - num_global
    s_idx = jnp.arange(wcap, dtype=jnp.int32)[None, :]
    last = jnp.asarray(total, jnp.int32).reshape(-1, 1) - 1
    t_ring = last - jnp.mod((last - g) - (s_idx - g), ring)
    t_s = jnp.where(s_idx < g, s_idx, t_ring)
    valid = jnp.where(s_idx < g, s_idx <= last, t_ring >= g)
    return t_s, valid & (s_idx < ring_cap)


def ring_insert_ref(cache, new, pos, num_new, *, ring_cap: int,
                    num_global: int):
    """Insert `new` (B, H, T, D) rows at their ring slots of `cache`
    (B, H, W, D): token pos+j -> slot g + (pos+j-g) mod ring (pinned below
    g); rows j >= num_new[b] are not written. Implemented as iota==slot
    selects (ascending j: last writer wins), the SPMD-safe form
    layers._dyn_update uses — for T=1 this is op-for-op that function."""
    b, _, wcap, _ = cache.shape
    t = new.shape[2]
    g, ring = num_global, ring_cap - num_global
    pos = jnp.asarray(pos, jnp.int32).reshape(b)
    num_new = jnp.asarray(num_new, jnp.int32).reshape(b)
    for j in range(t):
        pj = pos + j
        slot = jnp.where(pj < g, pj, g + jnp.mod(pj - g, ring))
        hit = ((jnp.arange(wcap, dtype=jnp.int32)[None, :] == slot[:, None])
               & (j < num_new)[:, None])[:, None, :, None]
        cache = jnp.where(hit, new[:, :, j:j + 1].astype(cache.dtype), cache)
    return cache


def decode_ref(q, k_cache, v_cache, cache_len, spec: AttentionSpec, *,
               scale: Optional[float] = None, total=None, q0=None,
               ring_cap: Optional[int] = None):
    """Decode T query tokens against a (ring) cache. q: (B, Hq, T, D),
    caches: (B, Hkv, W, D). Ring order is irrelevant (softmax is permutation
    invariant). Two masking modes:

    * prefix (total=None, the legacy T=1 call): only the first
      min(cache_len, W) entries are valid; no window/causal terms.
    * positional (total/q0 given, (B,)): every slot's absolute token index
      is reconstructed from the ring layout (`ring_slot_positions` —
      rotation modulus ring_cap, pinned prefix spec.num_global) and query
      token q0+t sees a slot iff its token is causally past and within
      spec.window (globals always). This is the oracle for the fused
      multi-token pallas kernel, including caches wider than the window.

    Numerics note: scores come from a mixed-precision dot_general with fp32
    accumulation — never from an fp32 *copy* of the cache. Materializing
    `k_cache.astype(f32)` doubles decode HBM traffic and shows up as a
    convert-op FLOP avalanche in the roofline (EXPERIMENTS.md §Perf it.1)."""
    b, hq, t, d = q.shape
    hkv, wcap = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, group * t, d)
    # (B, Hkv, G*T, W) <- (B, Hkv, G*T, D) x (B, Hkv, W, D), fp32 accumulate
    from repro.kernels import dots
    s = dots.dot_general_f32(
        qg, k_cache, (((3,), (3,)), ((0, 1), (0, 1)))) * scale
    s = _soft_cap(s, spec.softcap)
    if total is None:
        assert t == 1, "multi-token decode_ref needs positional masks"
        valid = (jnp.arange(wcap)[None, None, None, :]
                 < jnp.minimum(cache_len.reshape(b, 1, 1, 1), wcap))
    else:
        cap = wcap if ring_cap is None else ring_cap
        g = spec.num_global if spec.is_sparse else 0
        t_s, ok = ring_slot_positions(total, wcap, ring_cap=cap, num_global=g)
        trow = jnp.arange(group * t, dtype=jnp.int32) % t
        qp = (jnp.asarray(q0, jnp.int32).reshape(b, 1)
              + trow[None, :])                         # (B, G*T)
        vis = ok[:, None, :]                           # (B, G*T, W)
        if spec.causal:
            vis = vis & (t_s[:, None, :] <= qp[:, :, None])
        if spec.is_sparse and spec.window:
            keep = t_s[:, None, :] >= qp[:, :, None] - spec.window
            if g > 0:
                keep = keep | (jnp.arange(wcap) < g)[None, None, :]
            vis = vis & keep
        valid = vis[:, None]                           # (B, 1, G*T, W)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    out = dots.dot_general_f32(
        p.astype(v_cache.dtype), v_cache,
        (((3,), (2,)), ((0, 1), (0, 1))))          # (B, Hkv, G*T, D)
    return out.reshape(b, hq, t, d).astype(q.dtype)
