"""SWAT forward kernel: fused, exact-band, block-sparse window attention.

TPU adaptation of the paper's design (DESIGN.md §2):
  * exact-band compute     -> the grid's slot axis visits only the kv blocks
                              in the band (plus global/random blocks), driven
                              by a scalar-prefetched block map.
  * kernel fusion (Eq. 1)  -> QK^T, exp and the V accumulation happen in one
                              kernel; S/S' never leave VMEM; the denominator
                              divides once, at the last slot.
  * row-major dataflow     -> q-block-major grid order; consecutive q blocks
                              share all but one band kv block, so Pallas's
                              block pipeline re-fetches ~one K/V block per q
                              row of blocks (the FIFO's "load once").
  * input-stationary       -> inverted to output-stationary (MXU): the Z tile
                              accumulates in VMEM scratch across slots.

Numerics: fp32 accumulation, flash-style running max (deviation from the
paper's raw exp; see DESIGN.md §6). Masks use -1e30, not -inf, so fully
masked PAD slots contribute exactly 0 without NaN paths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import patterns
from repro.core.types import AttentionSpec

NEG_INF = -1e30
LANES = 128  # TPU lane width: m/l scratch is (BQ, LANES) with col-0 live


def element_mask(spec: AttentionSpec, q_idx, k_idx, seq_kv, kind):
    """Per-element visibility for one (q_block, kv_block) tile.

    band | global-columns | random-slot, then AND'd with causality and kv
    bounds. `kind` is the slot kind (PAD handled by the caller's pl.when;
    RANDOM slots get whole-block visibility, matching BigBird's block-random
    pattern). q_idx/k_idx are GLOBAL token coordinates — context parallelism
    passes shard-offset indices, so halo rows that fall before the sequence
    start (k_idx < 0 on the leftmost shard) mask out here."""
    mask = (k_idx < seq_kv) & (k_idx >= 0)
    if spec.is_sparse:
        band = k_idx >= q_idx - spec.window
        if not spec.causal:
            band &= k_idx <= q_idx + spec.window
        allowed = band
        if spec.num_global:
            allowed |= k_idx < spec.num_global
        if spec.num_random:
            allowed |= jnp.full_like(band, kind == patterns.RANDOM)
        mask &= allowed
    if spec.causal:
        mask &= k_idx <= q_idx
    return mask


def _attention_fwd_kernel(
    # scalar prefetch
    kv_map_ref, kinds_ref,
    # inputs
    q_ref, k_ref, v_ref,
    # outputs
    o_ref, lse_ref,
    # scratch
    m_ref, l_ref, acc_ref,
    *, spec: AttentionSpec, block_q: int, block_kv: int,
    seq_q: int, seq_kv: int, num_slots: int, scale: float,
    q_offset: int = 0, kv_offset: int = 0,
):
    i = pl.program_id(2)   # q block
    s = pl.program_id(3)   # kv slot
    kind = kinds_ref[i, s]
    j = kv_map_ref[i, s]

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kind != patterns.PAD)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
        st = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BQ, BK)
        if spec.softcap:
            st = spec.softcap * jnp.tanh(st / spec.softcap)

        q_idx = q_offset + i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_idx = kv_offset + j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = element_mask(spec, q_idx, k_idx, seq_kv, kind)
        st = jnp.where(mask, st, NEG_INF)

        m_prev = m_ref[:, :1]                             # (BQ, 1)
        m_cur = jnp.max(st, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(st - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (BK, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BQ, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s == num_slots - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)
                       ).astype(o_ref.dtype)
        # logsumexp per row, saved for the backward pass
        lse_ref[0, 0] = (m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
                         ).astype(jnp.float32)


def swat_attention_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, spec: AttentionSpec, *,
    pattern: Optional[patterns.BlockPattern] = None,
    block_q: int = 128, block_kv: int = 128,
    scale: Optional[float] = None, interpret: bool = False,
    return_lse: bool = False,
    q_offset: int = 0, kv_offset: int = 0,
    seq_kv_bound: Optional[int] = None,
):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D). Returns (B, Hq, Lq, D)
    (and row logsumexp (B, Hq, Lq) when return_lse).

    q_offset/kv_offset: global token coordinates of q[...,0,:] / k[...,0,:]
    (context parallelism — the mask sees global indices). seq_kv_bound: the
    GLOBAL kv length (defaults to kv_offset + Lkv, i.e. this buffer ends the
    sequence)."""
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(d ** -0.5 if scale is None else scale)
    if seq_kv_bound is None:
        seq_kv_bound = kv_offset + lkv
    if pattern is None:
        pattern = patterns.build_block_pattern(
            spec, lq, lkv, block_q, block_kv, q_shift=q_offset - kv_offset)
    block_q, block_kv = pattern.block_q, pattern.block_kv
    nq, num_slots = pattern.num_q_blocks, pattern.num_slots

    # pad sequence dims to block multiples (mask handles kv bounds; padded q
    # rows are truncated below)
    lq_pad, lkv_pad = nq * block_q, pattern.num_kv_blocks * block_kv
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0)))
    if lkv_pad != lkv:
        pad = ((0, 0), (0, 0), (0, lkv_pad - lkv), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)

    grid = (b, hq, nq, num_slots)
    kv_map = jnp.asarray(pattern.kv_block_map)
    kinds = jnp.asarray(pattern.slot_kinds)

    kernel = functools.partial(
        _attention_fwd_kernel, spec=spec, block_q=block_q, block_kv=block_kv,
        seq_q=lq, seq_kv=seq_kv_bound, num_slots=num_slots, scale=scale,
        q_offset=q_offset, kv_offset=kv_offset)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, i, s, bm, km: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, s, bm, km: (bb, h // group,
                                                      bm[i, s], 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, s, bm, km: (bb, h // group,
                                                      bm[i, s], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, i, s, bm, km: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, LANES),
                         lambda bb, h, i, s, bm, km: (bb, h, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )

    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, lq_pad, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, lq_pad, LANES), jnp.float32),
        ],
        interpret=interpret,
        name="swat_attention_fwd",
    )(kv_map, kinds, q, k, v)
    out = out[:, :, :lq]
    if return_lse:
        return out, lse[:, :, :lq, 0]
    return out
