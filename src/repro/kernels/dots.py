"""Mixed-precision matmul helper.

TPU MXUs compute bf16 x bf16 -> f32 natively (no fp32 copies of the
operands), which is what the kernels and the roofline assume. The CPU
backend can COMPILE that combination (the dry-run only lowers+compiles) but
cannot EXECUTE it — so execution paths on CPU upcast instead.

  native_mixed_dot(True)   dry-run lowering: keep operands bf16,
                           preferred_element_type=f32 (TPU semantics)
  native_mixed_dot(False)  CPU execution (tests/examples): upcast to f32

Default: native on TPU, upcast elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NATIVE = jax.default_backend() == "tpu"


def native_mixed_dot(value: bool) -> None:
    global _NATIVE
    _NATIVE = value


def einsum_f32(subscripts: str, a, b):
    """einsum with fp32 accumulation, without fp32 operand copies when the
    backend supports mixed dots."""
    if _NATIVE or a.dtype == jnp.float32:
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def dot_general_f32(a, b, dimension_numbers):
    if _NATIVE or a.dtype == jnp.float32:
        return jax.lax.dot_general(a, b, dimension_numbers,
                                   preferred_element_type=jnp.float32)
    return jax.lax.dot_general(a.astype(jnp.float32),
                               b.astype(jnp.float32), dimension_numbers,
                               preferred_element_type=jnp.float32)
