"""Public attention ops: jit-ready, differentiable, implementation-switched.

Implementations (impl=):
  pallas  - the SWAT Pallas kernels (custom_vjp; interpret mode on CPU).
            The TPU hot path.
  xla     - block-banded scan implementation. Same exact-band FLOPs as the
            Pallas kernel, pure jax.lax, natively differentiable and SPMD-
            partitionable: this is what the multi-pod dry-run lowers, so
            cost_analysis reflects banded compute without interpret-mode
            loop artifacts.
  sliding_chunks - the HuggingFace Longformer baseline (paper's comparison
            target, ~50% redundant FLOPs).
  ref     - O(N^2) masked reference (tests, tiny shapes).

Global tokens (Longformer) are composed here: the band+global-column kernel
covers every non-global row; a second dense pass over the first g rows
replaces their output — the TPU analogue of SWAT's dedicated global
attention cores. Autodiff flows through both passes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels import dots
from repro.kernels import ref as ref_impl
from repro.kernels import swat_attention as fwd_mod
from repro.kernels import swat_backward as bwd_mod

NEG_INF = fwd_mod.NEG_INF


@functools.lru_cache(maxsize=512)
def get_pattern(spec: AttentionSpec, seq_q: int, seq_kv: int,
                block_q: int, block_kv: int) -> patterns.BlockPattern:
    return patterns.build_block_pattern(spec, seq_q, seq_kv, block_q, block_kv)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _census_dispatch(q, k_cache, *, impl: str, fused: bool, cap: int,
                     g: int, window: int) -> None:
    """Trace-time dispatch census (telemetry.kernelprof, opt-in). jit
    traces each shape once, so recording here yields a complete
    shape -> dispatch map of what the engine compiled at ZERO runtime
    cost — the compiled program is byte-identical, census on or off."""
    from repro.telemetry import kernelprof as KP
    if not KP.census_enabled():
        return
    b, hq, t, d = q.shape
    KP.record_dispatch(op="decode_attention", impl=impl, fused=fused,
                       b=b, h_q=hq, h_kv=k_cache.shape[1], t=t, d=d,
                       cap=cap, num_global=g, window=window)


# --------------------------------------------------------------------------
# Pallas primitive with custom VJP (one block pattern)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _pallas_attention(q, k, v, spec, pattern, scale, interpret):
    out, _ = _pallas_fwd(q, k, v, spec, pattern, scale, interpret)
    return out


def _pallas_fwd(q, k, v, spec, pattern, scale, interpret):
    out, lse = fwd_mod.swat_attention_fwd(
        q, k, v, spec, pattern=pattern, scale=scale, interpret=interpret,
        return_lse=True)
    return out, (q, k, v, out, lse)


def _pallas_bwd(spec, pattern, scale, interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = bwd_mod.swat_attention_bwd(
        q, k, v, out, lse, do, spec, pattern=pattern, scale=scale,
        interpret=interpret)
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_fwd, _pallas_bwd)


# --------------------------------------------------------------------------
# XLA block-banded implementation (scan over q blocks)
# --------------------------------------------------------------------------

def _xla_dense(q, k, v, spec, scale):
    """Plain masked attention — the honest O(N^2) dense cost (the paper's
    GPU baseline). Used for dense specs so HLO FLOPs/bytes reflect true
    dense attention (flash-streaming is the Pallas kernel's job on TPU)."""
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    qb = q.reshape(b, hkv, group, lq, d) * jnp.asarray(scale, q.dtype)
    # mixed-precision dots with fp32 accumulation: no fp32 COPIES of K/V
    # (those double HBM traffic and dominate the convert-op flop count)
    s = dots.einsum_f32("bhgld,bhkd->bhglk", qb, k)
    if spec.softcap:
        s = spec.softcap * jnp.tanh(s / spec.softcap)
    if spec.causal:
        mask = (jnp.arange(lkv)[None, :] <= jnp.arange(lq)[:, None])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    den = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    o = dots.einsum_f32("bhglk,bhkd->bhgld", (p / den).astype(v.dtype), v)
    return o.reshape(b, hq, lq, d).astype(q.dtype)


def _xla_banded(q, k, v, spec, pattern, scale, *, q_shift: int = 0,
                kv_lo=None, kv_hi=None, return_partials: bool = False):
    """Vectorized exact-band attention: every q block gathers only its slot
    kv blocks — O(N * band) compute AND memory, no loop (so HLO cost
    analysis counts every FLOP; lax.scan bodies are counted once).

    Context-parallel hooks (all default to the plain single-buffer case):
      q_shift          - constant local-coordinate shift: q row i aligns with
                         kv row i + q_shift (the kv buffer carries a halo
                         prefix of q_shift rows). The pattern must be built
                         with the same q_shift. Static int.
      kv_lo / kv_hi    - valid kv half-open range in LOCAL coordinates. May
                         be traced scalars (per-shard edge masking inside
                         shard_map). Defaults: [0, pattern.seq_kv).
      return_partials  - return the flash state (acc, l, m) with
                         acc (B,H,L,D) fp32 unnormalized, l/m (B,H,L) fp32,
                         for cross-pass / cross-device logsumexp merging.
    """
    if not spec.is_sparse:
        assert q_shift == 0 and not return_partials
        return _xla_dense(q, k, v, spec, scale)
    if (q_shift == 0 and not return_partials and spec.num_random == 0
            and spec.window >= k.shape[2]
            and (spec.causal or spec.window >= q.shape[2])):
        # degenerate window (w >= seq): the band covers everything, but the
        # banded gather would still duplicate ~the whole KV once per q block
        # (nq x KV bytes — the gemma2 train_4k memory blow-up, §Perf cell 3
        # it.3). Fall through to the plain dense path instead.
        return _xla_dense(q, k, v, spec, scale)
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    bq, bk = pattern.block_q, pattern.block_kv
    nq, ns = pattern.num_q_blocks, pattern.num_slots
    lq_pad = nq * bq
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0)))
    lkv_pad = pattern.num_kv_blocks * bk
    if lkv_pad != lkv:
        pad = ((0, 0), (0, 0), (0, lkv_pad - lkv), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    if kv_lo is None:
        kv_lo = 0
    if kv_hi is None:
        kv_hi = pattern.seq_kv

    qb = q.reshape(b, hkv, group, nq, bq, d)
    kv_map = jnp.asarray(pattern.kv_block_map)        # (nq, ns)
    kinds = jnp.asarray(pattern.slot_kinds)           # (nq, ns)

    # gather all (nq, ns*bk) kv rows at once
    flat = (kv_map[:, :, None] * bk
            + jnp.arange(bk, dtype=jnp.int32)[None, None, :]
            ).reshape(nq, ns * bk)                    # (nq, S)
    kg = jnp.take(k, flat.reshape(-1), axis=2).reshape(
        b, hkv, nq, ns * bk, d)
    vg = jnp.take(v, flat.reshape(-1), axis=2).reshape(
        b, hkv, nq, ns * bk, d)

    s = dots.einsum_f32("bhgnqd,bhnkd->bhgnqk",
                        qb * jnp.asarray(scale, q.dtype), kg)
    if spec.softcap:
        s = spec.softcap * jnp.tanh(s / spec.softcap)

    q_idx = ((jnp.arange(nq)[:, None] * bq
              + jnp.arange(bq)[None, :])[:, :, None]
             + q_shift)                               # (nq, bq, 1)
    k_idx = flat[:, None, :]                          # (nq, 1, S)
    full = jnp.repeat(kinds, bk, axis=1)[:, None, :]  # (nq, 1, S)
    mask = (k_idx >= kv_lo) & (k_idx < kv_hi) & (full != patterns.PAD)
    band = k_idx >= q_idx - spec.window
    if not spec.causal:
        band &= k_idx <= q_idx + spec.window
    allowed = band
    if spec.num_global:
        allowed |= k_idx < spec.num_global
    if spec.num_random:
        allowed |= (full == patterns.RANDOM)
    mask &= allowed
    if spec.causal:
        mask &= k_idx <= q_idx

    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, None, None], p, 0.0)
    if return_partials:
        acc = dots.einsum_f32("bhgnqk,bhnkd->bhgnqd", p.astype(v.dtype), vg)
        acc = acc.astype(jnp.float32).reshape(b, hq, lq_pad, d)[:, :, :lq]
        l = jnp.sum(p, -1).reshape(b, hq, lq_pad)[:, :, :lq]
        mm = m[..., 0].reshape(b, hq, lq_pad)[:, :, :lq]
        return acc, l, mm
    den = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    o = dots.einsum_f32("bhgnqk,bhnkd->bhgnqd", (p / den).astype(v.dtype),
                        vg)
    o = o.astype(q.dtype).reshape(b, hq, lq_pad, d)
    return o[:, :, :lq]


# --------------------------------------------------------------------------
# Context-parallel dispatch (set by the launcher / dry-run, not per-call:
# the model stack stays signature-stable while the distribution strategy
# changes underneath — the same pattern as native_mixed_dot)
# --------------------------------------------------------------------------

_CP_CTX: Optional[tuple] = None   # (mesh, axis) | None


def set_context_parallel(mesh, axis: str = "model") -> None:
    """Enable halo-exchange context parallelism for every eligible
    swat_attention call (sparse spec, no random blocks, seq divisible by the
    axis with shards wider than the window's halo usefulness)."""
    global _CP_CTX
    _CP_CTX = (mesh, axis) if mesh is not None else None


def _cp_eligible(spec: AttentionSpec, lq: int, lkv: int) -> bool:
    if _CP_CTX is None or not spec.is_sparse or spec.num_random:
        return False
    mesh, axis = _CP_CTX
    n = mesh.shape[axis]
    return (lq == lkv and lq % n == 0 and lq // n >= 128
            and spec.num_global <= lq // n)


# --------------------------------------------------------------------------
# Public entry point
# --------------------------------------------------------------------------

def swat_attention(q, k, v, spec: AttentionSpec, *,
                   block_q: int = 128, block_kv: int = 128,
                   scale: Optional[float] = None,
                   impl: str = "pallas",
                   interpret: Optional[bool] = None):
    """Fused window/global/random attention. q: (B, Hq, Lq, D);
    k, v: (B, Hkv, Lkv, D). Differentiable for all impls."""
    b, hq, lq, d = q.shape
    lkv = k.shape[2]
    scale = float(d ** -0.5 if scale is None else scale)
    interpret = default_interpret() if interpret is None else interpret

    if _cp_eligible(spec, lq, lkv):
        from repro.distributed import context_parallel as CP
        mesh, axis = _CP_CTX
        return CP.swat_attention_context_parallel(
            q, k, v, spec, mesh=mesh, axis=axis,
            block_q=block_q, block_kv=block_kv, scale=scale)

    if impl == "ref":
        pat = get_pattern(spec, lq, lkv, block_q, block_kv)
        return ref_impl.attention_ref(q, k, v, spec, pattern=pat, scale=scale)
    if impl == "sliding_chunks":
        return ref_impl.sliding_chunks_ref(q, k, v, spec, scale=scale)
    assert impl in ("pallas", "xla"), impl

    pat = get_pattern(spec, lq, lkv, block_q, block_kv)
    if impl == "pallas":
        out = _pallas_attention(q, k, v, spec, pat, scale, interpret)
    else:
        out = _xla_banded(q, k, v, spec, pat, scale)

    g = spec.num_global
    if spec.is_sparse and g > 0:
        # dense pass for global rows (paper §4.1's pinned global cores)
        gspec = dataclasses.replace(spec, kind="dense", window=0,
                                    num_global=0, num_random=0)
        gpat = get_pattern(gspec, g, lkv, block_q, block_kv)
        qg = q[:, :, :g]
        if impl == "pallas":
            og = _pallas_attention(qg, k, v, gspec, gpat, scale, interpret)
        else:
            og = _xla_banded(qg, k, v, gspec, gpat, scale)
        out = jnp.concatenate([og, out[:, :, g:]], axis=2)
    return out


def _per_slot(x, b: int):
    """Normalize scalar / (B,) / (B,1,1,1) spellings to (B,) int32:
    broadcast, never reshape — a scalar reshaped to (B,) crashes for B > 1
    even though a shared length is the common cross-attention case
    (model.py passes a full()'d (B,1,1,1))."""
    x = jnp.asarray(x, jnp.int32)
    return jnp.broadcast_to(x.reshape(()) if x.size == 1 else x.reshape(b),
                            (b,))


def decode_attention(q, k_cache, v_cache, cache_len, spec: AttentionSpec, *,
                     scale: Optional[float] = None, impl: str = "ref",
                     interpret: Optional[bool] = None,
                     new_kv=None, num_new=None, pos=None,
                     ring_cap: Optional[int] = None):
    """Decode T >= 1 tokens vs a (ring) KV cache. q: (B, Hq, T, D).
    cache_len / pos / num_new are per-slot (scalar, (B,) or (B,1,1,1)): a
    continuously-batched engine serves slots at different ring depths from
    this one call.

    * plain (new_kv=None): the cache already holds everything;
      `cache_len` is the valid count and the query tokens are its newest.
    * fused (new_kv=(k_new, v_new), each (B, Hkv, T, D)): the step's K/V
      rows are inserted at their ring slots AND attended in the same pass —
      on the pallas impl inside the kernel's VMEM-resident block (no
      separate scatter dispatch, no second full-cache HBM round trip);
      the ref impl scatters then attends (the unfused parity oracle —
      identical masks, op-for-op the engine's pre-fusion jnp path).
      `pos` (required) counts tokens BEFORE the insert; `num_new` optionally
      ragged-limits how many of the T rows are real per slot (speculative
      accepts). Returns (out, k_cache, v_cache).

    Masks are positional: `ring_cap` is the LOGICAL rotation modulus
    (defaults to the cache width), spec.num_global the pinned prefix, and
    spec.window is enforced by token distance — so a cache allocated wider
    than window+1 rows (lookahead rings, dense caps) no longer attends its
    entire valid prefix (the old behavior silently dropped the window).

    impl="ref" is the jnp path (CPU tests, dry-run lowering); "pallas" is
    the swat_decode flash kernel (the TPU hot path; interpret mode
    elsewhere). Ring order is irrelevant either way — softmax is
    permutation invariant."""
    b, _, t, _ = q.shape
    w_phys = k_cache.shape[2]
    cap = w_phys if ring_cap is None else int(ring_cap)
    g = spec.num_global if spec.is_sparse else 0
    window = spec.window if spec.is_sparse else 0
    fuse = new_kv is not None
    if fuse:
        assert pos is not None, "fused insert needs per-slot `pos`"
        assert t <= cap - g, (
            f"{t} new tokens would overwrite each other in a {cap - g}-row "
            "ring: allocate the cache with lookahead >= T-1")
        assert t == 1 or not (spec.is_sparse and spec.window) \
            or cap - g >= spec.window + t, (
                f"T={t} fused decode on a {cap - g}-row ring would evict "
                "tokens still inside early queries' windows (sequential "
                "equivalence needs ring >= window + T): allocate with "
                "lookahead >= T-1")
    if (spec.is_sparse and spec.window and cap > spec.window + 1 + g
            and pos is None):
        # cache_len is CLAMPED (min(step, cap)) and loses the ring phase:
        # reconstructing slot positions from it on a wrapped wider-than-band
        # ring would window-mask the wrong slots — silently. Demand the
        # absolute count instead of guessing.
        raise ValueError(
            "window masking on a cache wider than window+1+globals needs "
            "absolute per-slot `pos=` (cache_len is clamped and loses the "
            "ring phase after a wrap)")
    assert fuse or cache_len is not None or pos is not None, (
        "plain decode needs cache_len (valid prefix) or pos (absolute "
        "token count) — with neither, every slot would mask empty and the "
        "output would be silently all-zero")
    cl = _per_slot(cache_len if cache_len is not None else 0, b)
    pos = cl if pos is None else _per_slot(pos, b)
    nn = (jnp.full((b,), t, jnp.int32) if num_new is None
          else _per_slot(num_new, b))
    _census_dispatch(q, k_cache, impl=impl, fused=fuse, cap=cap, g=g,
                     window=window)
    if impl == "pallas":
        from repro.kernels.swat_decode import swat_decode
        interpret = default_interpret() if interpret is None else interpret
        k_new, v_new = new_kv if fuse else (None, None)
        return swat_decode(q, k_cache, v_cache, pos,
                           new_k=k_new, new_v=v_new,
                           num_new=nn if fuse else None,
                           ring_cap=cap, num_global=g, window=window,
                           causal=spec.causal, scale=scale,
                           softcap=spec.softcap, interpret=interpret)
    if fuse:
        k_new, v_new = new_kv
        k_cache = ref_impl.ring_insert_ref(k_cache, k_new, pos, nn,
                                           ring_cap=cap, num_global=g)
        v_cache = ref_impl.ring_insert_ref(v_cache, v_new, pos, nn,
                                           ring_cap=cap, num_global=g)
        out = ref_impl.decode_ref(q, k_cache, v_cache, None, spec,
                                  scale=scale, total=pos + nn, q0=pos,
                                  ring_cap=cap)
        return out, k_cache, v_cache
    if t > 1 or (spec.is_sparse and spec.window
                 and cap > spec.window + 1 + g):
        # positional masks: multi-token queries need per-token causality,
        # and a cache wider than the band would otherwise attend stale
        # tokens through the prefix mask alone (the bug this path fixes).
        # Queries are the cache's newest tokens (pre-inserted convention).
        return ref_impl.decode_ref(q, k_cache, v_cache, None, spec,
                                   scale=scale, total=pos, q0=pos - t,
                                   ring_cap=cap)
    return ref_impl.decode_ref(q, k_cache, v_cache, cl, spec, scale=scale)
