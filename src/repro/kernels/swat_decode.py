"""SWAT flash-decode kernel: T new tokens vs a ring-buffer KV cache, with
the ring insert fused into the attention pass.

The paper's FIFO K/V buffer with a moving replacement pointer (Fig. 4b) *is*
a ring KV cache: decode with window attention keeps exactly W = 2w (or w for
causal lookback) K/V rows per layer and evicts slot (step mod W). The paper's
input-stationary dataflow — the band stays resident while compute streams
over it — is exactly what the fused insert reproduces on TPU: the kernel
already holds each cache block in VMEM for the attention pass, so it writes
the step's new K/V rows into that same block (input/output aliasing) instead
of paying a separate full-cache scatter dispatch per layer per token. One
kernel = replacement-pointer write + exact-band attention, the same fusion
argument SWAT makes against unfused FPGA baselines.

Grid: (B, Hkv, W/BK). The query tile packs the `group = Hq/Hkv` heads that
share a KV head times the T new tokens into one (group*T, D) block, so GQA
configs drive the MXU with a real tile instead of a (1, D) row (~1/128 MXU
utilization at group=1). T > 1 is the multi-token primitive speculative
decode verifies drafts with.

Masks are computed from PER-SLOT ring positions (`pos`, scalar-prefetched):
each cache slot's absolute token index is reconstructed from the ring
arithmetic, so one call serves a continuous batch of slots at arbitrary
depths, cold/partially-filled/multiply-wrapped alike, AND the window is
enforced by token distance — a cache allocated wider than window+1 rows
(lookahead rings, dense-capped allocations) masks out in-ring-but-stale
tokens instead of attending the whole valid prefix. Ring rotation never
needs un-rotating (softmax is permutation invariant); only the masks depend
on depth.
"""
from __future__ import annotations

import functools
import logging
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.swat_attention import LANES, NEG_INF

logger = logging.getLogger(__name__)
_PAD_WARNED: set = set()
_FORCE_FAIL = False


def set_force_fail(enabled: bool) -> None:
    """Arm/disarm the simulated dispatch failure: while armed, every
    `swat_decode` call raises `KernelDispatchError` at entry — the serving
    engine's graceful-degradation ladder catches it and falls back to the
    ref decode impl. Trace-time, so an armed scan compile fails before any
    donated buffer is consumed (retrying with the ref impl is safe).
    Module-global: the fault harness (`serving.faults`) manages it."""
    global _FORCE_FAIL
    _FORCE_FAIL = enabled
_PAD_EVENTS: list = []


def consume_pad_events() -> list:
    """Drain the structured pad-fallback record (one dict per offending W
    seen since the last drain). The static analyzer (repro.analysis) calls
    this after tracing an entry point so an odd window size surfaces as a
    warn-level finding in ANALYSIS.json instead of only a log line."""
    out, _PAD_EVENTS[:] = list(_PAD_EVENTS), []
    return out


def _warn_pad(w: int, requested: int, chosen: int) -> None:
    """One-time (per W) warning for the pad-and-copy fallback: padding the
    cache to a block multiple COPIES the whole cache every decode call —
    engine ring allocations are pre-rounded to avoid it, so hitting this
    means an ad-hoc capacity leaked into a hot path. Names both the
    requested block and the block the kernel actually RUNS with, so the log
    line alone says what the padded grid looks like. Every distinct W is
    also recorded as a structured event for the analyzer (the log dedups
    per process; the event buffer dedups per drain)."""
    if not any(e.get("kind") == "pad" and e["w"] == w for e in _PAD_EVENTS):
        _PAD_EVENTS.append({"kind": "pad", "w": w, "block_kv": requested,
                            "chosen_block": chosen,
                            "padded_w": -(-w // chosen) * chosen,
                            "min_block": _MIN_BLOCK_KV})
    if w in _PAD_WARNED:
        return
    _PAD_WARNED.add(w)
    logger.warning(
        "swat_decode: cache capacity W=%d is not tileable by block_kv=%d "
        "(no divisor >= %d): running with block_kv=%d over a jnp.pad-ed "
        "%d-row cache, which copies the ENTIRE cache on every call. Round "
        "the allocation (layers.cache_allocation) if this is a hot path.",
        w, requested, _MIN_BLOCK_KV, chosen, -(-w // chosen) * chosen)


def record_paged_fallback(nb: int, page: int, reason: str) -> None:
    """Structured event for paged-KV decode taking the materialized
    gather-view path instead of an in-kernel block gather (the table is
    resolved OUTSIDE the kernel, costing a pool-sized copy per step).
    Shares the `_PAD_EVENTS` channel so the analyzer surfaces it next to
    the pad-and-copy fallback — both are 'the hot path is copying the
    cache' findings. Deduped per (nb, page) per drain."""
    if any(e.get("kind") == "paged_gather" and e.get("nb") == nb
           and e.get("page") == page for e in _PAD_EVENTS):
        return
    _PAD_EVENTS.append({"kind": "paged_gather", "nb": nb, "page": page,
                        "reason": reason})


def _pmod(x, m: int):
    """Floored (always non-negative) remainder by a static positive int."""
    r = jax.lax.rem(x, m)
    return r + jnp.where(r < 0, m, 0)


def _decode_kernel(pos_ref, nn_ref, q_ref, k_ref, v_ref, *rest,
                   block_kv: int, num_blocks: int, rows: int, t_span: int,
                   g: int, ring: int, cap: int, window: int, causal: bool,
                   fuse: bool, scale: float, softcap: float):
    if fuse:
        nk_ref, nv_ref, o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = pos_ref[b]
    base = s * block_kv
    k = k_ref[0, 0]                                      # (BK, D) cache dtype
    v = v_ref[0, 0]
    if fuse:
        nn = nn_ref[b]
        total = p + nn
        q0 = p
        # input-stationary ring insert: the new rows land in the block the
        # attention pass already holds in VMEM; the blended block is both
        # attended and written back through the aliased output.
        for j in range(t_span):
            pj = p + j
            slot = jnp.where(pj < g, pj, g + _pmod(pj - g, ring))
            ok = (j < nn) & (slot >= base) & (slot < base + block_kv)
            hit = (jax.lax.broadcasted_iota(jnp.int32, (block_kv, 1), 0)
                   == slot - base) & ok
            k = jnp.where(hit, nk_ref[0, 0, j][None, :], k)
            v = jnp.where(hit, nv_ref[0, 0, j][None, :], v)
        ko_ref[0, 0] = k
        vo_ref[0, 0] = v
    else:
        total = p
        q0 = p - t_span          # pre-inserted queries: last query == newest

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, D)
    st = jax.lax.dot_general(q, k.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (rows, BK)
    if softcap:
        st = softcap * jnp.tanh(st / softcap)

    # reconstruct each slot's absolute token index from the ring layout:
    # pinned slot s holds token s; ring slot r holds the newest token
    # congruent to r below `total`. Everything else is masking by position.
    s_idx = base + jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 1)
    last = total - 1
    t_ring = last - _pmod((last - g) - (s_idx - g), ring)
    if g > 0:
        t_s = jnp.where(s_idx < g, s_idx, t_ring)
        valid = jnp.where(s_idx < g, s_idx < total, t_ring >= g)
    else:
        t_s = t_ring
        valid = t_ring >= 0
    valid &= s_idx < cap
    trow = jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 0) % t_span
    qp = q0 + trow                                       # query token index
    vis = valid
    if causal:
        vis &= t_s <= qp
    if window:
        keep = t_s >= qp - window
        if g > 0:
            keep |= s_idx < g
        vis &= keep
    st = jnp.where(vis, st, NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(st, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pr = jnp.exp(st - m_new)
    pr = jnp.where(vis, pr, 0.0)
    l_ref[...] = jnp.broadcast_to(l_ref[:, :1] * alpha
                                  + jnp.sum(pr, -1, keepdims=True),
                                  l_ref.shape)
    pv = jax.lax.dot_general(pr, v.astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (rows, D)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(s == num_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


_MIN_BLOCK_KV = 16  # bf16 sublane tile: smallest usable (BK, D) block


def decode_block_kv(w: int, block_kv: int = 128) -> Tuple[int, bool]:
    """Resolve the kv block for a cache of W rows: (block, needs_pad).

    Prefer the largest divisor of W that is <= block_kv and sublane-aligned —
    then the grid tiles the cache EXACTLY and the hot path never copies.
    `init_kv_cache` ring allocations are pre-rounded (layers.cache_allocation
    — logical window semantics untouched, only zero tail rows) so engine ring
    caches always hit the no-pad path; ad-hoc W (odd test shapes, dense caps
    at unaligned max_len) fall back to the old pad-and-copy (and log a
    one-time warning naming the offending W)."""
    if w % block_kv == 0:
        return block_kv, False
    if w <= block_kv and w % _MIN_BLOCK_KV == 0:
        return w, False
    g = math.gcd(w, block_kv)
    if g >= _MIN_BLOCK_KV:
        return g, False
    return block_kv, True


def swat_decode(q, k_cache, v_cache, pos, *,
                new_k=None, new_v=None, num_new=None,
                ring_cap: Optional[int] = None, num_global: int = 0,
                window: int = 0, causal: bool = True, pack_gqa: bool = True,
                block_kv: int = 128, scale: Optional[float] = None,
                softcap: float = 0.0, interpret: bool = False):
    """q: (B, Hq, T, D); caches: (B, Hkv, W, D); pos: int32 (B,).

    Two modes share one kernel:

    * plain (new_k=None): the cache already holds everything. `pos` is the
      number of tokens in the cache — the T query tokens are its newest.
      Legacy single-token calls passing the valid prefix length are
      backward compatible at window=0 (dense prefix semantics); with
      window > 0, `pos` must be the ABSOLUTE token count — a clamped
      prefix length loses the ring phase after a wrap and would mask the
      wrong slots (ops.decode_attention enforces this).
      Returns out (B, Hq, T, D).
    * fused (new_k/new_v given, (B, Hkv, T, D)): `pos` counts the tokens in
      the cache BEFORE this call; the kernel writes the new rows into their
      ring slots (token pos+j -> slot g + (pos+j-g) mod ring, pinned slots
      below num_global) in the VMEM-resident block and attends the blended
      result — no separate scatter pass, no second full-cache HBM round
      trip. The updated caches come back through input/output aliasing:
      returns (out, k_cache, v_cache). num_new: optional (B,) count of REAL
      new tokens per slot (ragged speculative accepts); rows j >= num_new
      are neither written nor attendable and their outputs are garbage the
      caller discards.

    Masking is positional (see module docstring): ring_cap is the LOGICAL
    rotation modulus (defaults to W), num_global the pinned prefix, window
    the causal lookback (0 = no band — dense prefix semantics). The kv
    block adapts to W (`decode_block_kv`) so ring capacities that aren't a
    multiple of the default block never jnp.pad — the pad is a full cache
    COPY per token per layer, dwarfing the attention itself."""
    if _FORCE_FAIL:
        from repro.serving.faults import KernelDispatchError
        raise KernelDispatchError(
            "injected pallas dispatch failure (set_force_fail armed)")
    b, hq, t, d = q.shape
    _, hkv, w, _ = k_cache.shape
    group = hq // hkv
    fuse = new_k is not None
    cap = w if ring_cap is None else int(ring_cap)
    g = int(num_global)
    ring = cap - g
    assert ring > 0, (cap, g)
    assert not fuse or new_v is not None
    assert not fuse or pack_gqa, "fused insert requires the packed layout"
    assert not fuse or t <= ring, (
        f"{t} new tokens would overwrite each other in a {ring}-row ring: "
        "allocate the cache with lookahead >= T-1")
    scale = float(d ** -0.5 if scale is None else scale)
    requested_block = block_kv
    block_kv, needs_pad = decode_block_kv(w, block_kv)
    from repro.telemetry import kernelprof as KP
    if KP.census_enabled():
        # trace-time only (see ops._census_dispatch): records the block
        # geometry the kernel actually RUNS with, pad fallback included
        KP.record_dispatch(op="swat_decode", b=b, h_q=hq, h_kv=hkv, t=t,
                           d=d, w=w, cap=cap, num_global=g,
                           window=int(window), fused=fuse,
                           block_kv=block_kv, needs_pad=needs_pad)
    if needs_pad:
        _warn_pad(w, requested_block, block_kv)
        w_pad = -(-w // block_kv) * block_kv
        padw = ((0, 0), (0, 0), (0, w_pad - w), (0, 0))
        k_cache, v_cache = jnp.pad(k_cache, padw), jnp.pad(v_cache, padw)
    else:
        w_pad = w
    nb = w_pad // block_kv
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    if num_new is None:
        nn = jnp.full((b,), t, jnp.int32)
    else:
        nn = jnp.broadcast_to(jnp.asarray(num_new, jnp.int32).reshape(-1),
                              (b,))

    if pack_gqa:
        rows, grid_h = group * t, hkv
        qk = q.reshape(b, hkv, rows, d)
        kv_head = lambda h: h
    else:
        rows, grid_h = t, hq
        qk = q
        kv_head = lambda h: h // group

    kern = functools.partial(
        _decode_kernel, block_kv=block_kv, num_blocks=nb, rows=rows,
        t_span=t, g=g, ring=ring, cap=cap, window=int(window),
        causal=bool(causal), fuse=fuse, scale=scale, softcap=softcap)
    in_specs = [
        pl.BlockSpec((1, 1, rows, d), lambda bb, h, s, *_: (bb, h, 0, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda bb, h, s, *_: (bb, kv_head(h), s, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda bb, h, s, *_: (bb, kv_head(h), s, 0)),
    ]
    o_spec = pl.BlockSpec((1, 1, rows, d), lambda bb, h, s, *_: (bb, h, 0, 0))
    o_shape = jax.ShapeDtypeStruct((b, grid_h, rows, d), q.dtype)
    scratch = [pltpu.VMEM((rows, LANES), jnp.float32),
               pltpu.VMEM((rows, LANES), jnp.float32),
               pltpu.VMEM((rows, d), jnp.float32)]
    if fuse:
        new_k = new_k.astype(k_cache.dtype)
        new_v = new_v.astype(v_cache.dtype)
        kv_spec = pl.BlockSpec((1, 1, block_kv, d),
                               lambda bb, h, s, *_: (bb, h, s, 0))
        in_specs += [
            pl.BlockSpec((1, 1, t, d), lambda bb, h, s, *_: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bb, h, s, *_: (bb, h, 0, 0)),
        ]
        out = pl.pallas_call(
            kern,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(b, grid_h, nb),
                in_specs=in_specs,
                out_specs=[o_spec, kv_spec, kv_spec],
                scratch_shapes=scratch,
            ),
            out_shape=[o_shape,
                       jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
                       jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype)],
            # operands: (pos, nn, q, k_cache, v_cache, new_k, new_v) —
            # the caches update in place (input-stationary, Fig. 4b)
            input_output_aliases={3: 1, 4: 2},
            interpret=interpret, name="swat_decode_fused",
        )(pos, nn, qk, k_cache, v_cache, new_k, new_v)
        o, k_out, v_out = out
        o = o.reshape(b, hq, t, d)
        if needs_pad:
            k_out, v_out = k_out[:, :, :w], v_out[:, :, :w]
        return o, k_out, v_out

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, grid_h, nb),
            in_specs=in_specs,
            out_specs=[o_spec],
            scratch_shapes=scratch,
        ),
        out_shape=[o_shape],
        interpret=interpret, name="swat_decode",
    )(pos, nn, qk, k_cache, v_cache)[0]
    return out.reshape(b, hq, t, d)
