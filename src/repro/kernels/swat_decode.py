"""SWAT decode kernel: one new token vs a ring-buffer KV cache.

The paper's FIFO K/V buffer with a moving replacement pointer (Fig. 4b) *is*
a ring KV cache: decode with window attention keeps exactly W = 2w (or w for
causal lookback) K/V rows per layer and evicts slot (step mod W). Because
softmax is permutation-invariant, attention never needs to un-rotate the
ring — the kernel just masks cold (not-yet-filled) slots.

Grid: (B, Hq, W/BK). One query row per (batch, head); flash accumulation
across cache blocks in VMEM scratch. cache lengths are scalar-prefetched so
the index maps and masks stay static.

cache_len is PER SLOT: each batch row masks its own valid prefix, so a
continuous-batching engine feeds slots at arbitrary, different ring write
positions through one kernel call — the serving-side payoff of the FIFO
buffer. Ring rotation never needs un-rotating (softmax is permutation
invariant); only the cold-slot mask depends on per-slot depth.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.swat_attention import LANES, NEG_INF


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, block_kv: int, num_blocks: int, scale: float,
                   softcap: float):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    st = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, BK)
    if softcap:
        st = softcap * jnp.tanh(st / softcap)
    k_idx = s * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv),
                                                    1)
    st = jnp.where(k_idx < len_ref[b], st, NEG_INF)

    m_prev = m_ref[:1, :1]
    m_new = jnp.maximum(m_prev, jnp.max(st, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(st - m_new)
    p = jnp.where(k_idx < len_ref[b], p, 0.0)
    l_ref[...] = jnp.broadcast_to(l_ref[:1, :1] * alpha
                                  + jnp.sum(p, -1, keepdims=True), l_ref.shape)
    v = v_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, D)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(s == num_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:1, :1], 1e-30)).astype(o_ref.dtype)


_MIN_BLOCK_KV = 16  # bf16 sublane tile: smallest usable (BK, D) block


def decode_block_kv(w: int, block_kv: int = 128) -> Tuple[int, bool]:
    """Resolve the kv block for a cache of W rows: (block, needs_pad).

    Prefer the largest divisor of W that is <= block_kv and sublane-aligned —
    then the grid tiles the cache EXACTLY and the hot path never copies.
    `init_kv_cache` ring allocations are pre-rounded (layers.cache_allocation
    — logical window semantics untouched, only zero tail rows) so engine ring
    caches always hit the no-pad path; ad-hoc W (odd test shapes, dense caps
    at unaligned max_len) fall back to the old pad-and-copy."""
    if w % block_kv == 0:
        return block_kv, False
    if w <= block_kv and w % _MIN_BLOCK_KV == 0:
        return w, False
    g = math.gcd(w, block_kv)
    if g >= _MIN_BLOCK_KV:
        return g, False
    return block_kv, True


def swat_decode(q, k_cache, v_cache, cache_len, *,
                block_kv: int = 128, scale: Optional[float] = None,
                softcap: float = 0.0, interpret: bool = False):
    """q: (B, Hq, 1, D); caches: (B, Hkv, W, D); cache_len: int32 (B,) valid
    entries (ring: min(step, W)). Returns (B, Hq, 1, D).

    The kv block adapts to W (`decode_block_kv`) so ring capacities that
    aren't a multiple of the default block never jnp.pad — the pad was a
    full cache COPY per token per layer, dwarfing the attention itself."""
    b, hq, one, d = q.shape
    assert one == 1
    _, hkv, w, _ = k_cache.shape
    group = hq // hkv
    scale = float(d ** -0.5 if scale is None else scale)
    block_kv, needs_pad = decode_block_kv(w, block_kv)
    if needs_pad:
        w_pad = -(-w // block_kv) * block_kv
        pad = ((0, 0), (0, 0), (0, w_pad - w), (0, 0))
        k_cache, v_cache = jnp.pad(k_cache, pad), jnp.pad(v_cache, pad)
    else:
        w_pad = w
    nb = w_pad // block_kv
    cache_len = jnp.minimum(jnp.asarray(cache_len, jnp.int32).reshape(b), w)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_kv=block_kv, num_blocks=nb,
                          scale=scale, softcap=softcap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hq, nb),
            in_specs=[
                pl.BlockSpec((1, 1, 1, d), lambda bb, h, s, ln: (bb, h, 0, 0)),
                pl.BlockSpec((1, 1, block_kv, d),
                             lambda bb, h, s, ln: (bb, h // group, s, 0)),
                pl.BlockSpec((1, 1, block_kv, d),
                             lambda bb, h, s, ln: (bb, h // group, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, d),
                                   lambda bb, h, s, ln: (bb, h, 0, 0)),
            scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32),
                            pltpu.VMEM((1, LANES), jnp.float32),
                            pltpu.VMEM((1, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=interpret, name="swat_decode",
    )(cache_len, q, k_cache, v_cache)
    return out
