"""Property tests for admission scheduling and block sizing.

Runs under the real `hypothesis` when installed, else the deterministic
tests/hypothesis_fallback.py shim (the CI spec-decode lane's mode). The
properties pinned here are the resilience PR's admission invariants:

  * `_block_len` over any mix of live budgets never overshoots the
    earliest completion (slots retire exactly at block boundaries, the
    invariant the scan==stepwise RNG guarantee rides on),
  * sub-quantum tail requeue keeps FCFS order exactly — admitted ++
    requeued ++ untouched is the original queue,
  * under a queue full of malformed requests, every rejection carries the
    right reason, every valid request still admits, and nothing is ever
    dropped on the floor (admitted + rejected == submitted).
"""
import collections
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # tier-1 bare env
    from hypothesis_fallback import given, settings, strategies as st

from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import Scheduler


class _EngineStub:
    """Just the slot state `_block_len` reads — no model, no devices."""

    def __init__(self, budgets, free, scan_steps):
        self.slot_budget = np.asarray(budgets, np.int32)
        self.slot_free = list(free)
        self.slots = len(budgets)
        self.scan_steps = scan_steps


@settings(deadline=None, max_examples=40)
@given(scan_steps=st.integers(1, 16),
       seed=st.integers(0, 10_000))
def test_block_len_never_overshoots_any_live_slot(scan_steps, seed):
    rng = np.random.RandomState(seed)
    slots = int(rng.randint(1, 9))
    budgets = rng.randint(1, 64, size=slots)
    free = rng.rand(slots) < 0.4
    n = ServingEngine._block_len(
        _EngineStub(budgets, free, scan_steps))
    live = [int(b) for b, f in zip(budgets, free) if not f]
    if not live:
        assert n == 0
        return
    assert 1 <= n <= scan_steps
    # the invariant everything else rides on: no live slot's budget is
    # overshot, and the earliest completion lands exactly on the boundary
    assert all(n <= b for b in live)
    assert n == min(min(live), scan_steps)


@settings(deadline=None, max_examples=40)
@given(quantum=st.integers(1, 5),
       num_free=st.integers(1, 12),
       seed=st.integers(0, 10_000))
def test_subquantum_tail_requeue_preserves_fcfs(quantum, num_free, seed):
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 14))
    lens = rng.randint(1, 24, size=n)
    pending = collections.deque(
        Request(rid=i, prompt=np.ones((int(l),), np.int32))
        for i, l in enumerate(lens))
    sched = Scheduler(max_prefill_tokens=8192, pad_to=16,
                      slot_quantum=quantum)
    plan = sched.plan(pending, num_free=num_free)
    assert plan is not None
    took = [r.rid for r in plan.requests]
    left = [r.rid for r in pending]
    # FCFS exactly: what was admitted is the queue's head, what remains
    # (requeued tail included) is the rest, in submission order
    assert took + left == list(range(n))
    assert len(took) <= num_free
    # divisibility-aware trim: any batch larger than one quantum is a
    # quantum multiple (a lone sub-quantum batch still admits — liveness)
    if len(took) > quantum:
        assert len(took) % quantum == 0
    assert sched.take_rejected() == []


@settings(deadline=None, max_examples=40)
@given(vocab=st.integers(8, 64),
       max_prompt_len=st.integers(4, 32),
       seed=st.integers(0, 10_000))
def test_rejections_under_full_queue_account_for_everything(
        vocab, max_prompt_len, seed):
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 16))
    reqs, expect_bad = [], {}
    for i in range(n):
        flavor = rng.randint(0, 5)
        if flavor == 0:
            prompt = np.zeros((0,), np.int32)
            expect_bad[i] = "empty prompt"
        elif flavor == 1:
            prompt = np.full((3,), vocab + 5, np.int32)
            expect_bad[i] = "out of range"
        elif flavor == 2:
            prompt = np.array([-1, 1], np.int32)
            expect_bad[i] = "out of range"
        elif flavor == 3:
            prompt = np.ones((max_prompt_len + 1,), np.int32)
            expect_bad[i] = "longer than max_prompt_len"
        else:
            length = int(rng.randint(1, max_prompt_len + 1))
            prompt = rng.randint(0, vocab, size=length).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt))
    pending = collections.deque(reqs)
    sched = Scheduler(max_prefill_tokens=8192, pad_to=16,
                      max_prompt_len=max_prompt_len, vocab_size=vocab)
    admitted, rejected = [], []
    while pending:
        plan = sched.plan(pending, num_free=4)
        rejected += sched.take_rejected()
        if plan is not None:
            admitted += [r.rid for r in plan.requests]
        else:
            assert not pending    # None only once everything drained
    # total accounting: nothing dropped, nothing served twice
    assert sorted(admitted + [r.rid for r, _ in rejected]) == list(range(n))
    assert sorted(r.rid for r, _ in rejected) == sorted(expect_bad)
    for req, reason in rejected:
        assert expect_bad[req.rid] in reason, (expect_bad[req.rid], reason)
