"""AdamW + int8 error-feedback compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.optim import adamw, compress


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, grad_clip=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                            weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_opt_state(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.apply_updates(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # norm reported unclipped


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(cfg, jnp.asarray(100)))
               - 0.1) < 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_compression_error_feedback_bounded(seed):
    """EF property: the compounded error (residual) stays bounded by one
    quantization step — compressed-sum converges to true-sum."""
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
    residual = compress.init_residual(g)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for t in range(20):
        gt = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
        ct, residual = compress.compress_decompress(gt, residual)
        acc_true += np.asarray(gt["w"])
        acc_comp += np.asarray(ct["w"])
    # residual carries exactly the difference
    np.testing.assert_allclose(
        acc_true, acc_comp + np.asarray(residual["w"]), atol=1e-3)
    scale = max(1e-12, np.abs(acc_true).max())
    assert np.abs(acc_true - acc_comp).max() / scale < 0.5


def test_compression_roundtrip_small_error():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(1000), jnp.float32)}
    r = compress.init_residual(g)
    c, _ = compress.compress_decompress(g, r)
    rel = float(jnp.linalg.norm(c["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02  # int8 per-tensor quantization error
