"""Tests run on the default single CPU device — the 512-device dry-run sets
its own XLA flags in a separate process (tests/test_dryrun.py uses
subprocesses for the same reason)."""
import os

# keep any inherited forced-device-count out of the test process
flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "device_count" not in f)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
