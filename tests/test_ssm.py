"""Mamba2 SSD: chunked dual form vs the sequential recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import ssm as S
from repro.core.types import SSMSpec


def rand_inputs(rng, b=2, l=96, h=4, p=16, g=2, sd=8):
    x = jnp.asarray(rng.randn(b, l, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, l, h)) * 0.1, jnp.float32)
    a = -jnp.asarray(np.abs(rng.randn(h)) + 0.3, jnp.float32)
    bm = jnp.asarray(rng.randn(b, l, g, sd), jnp.float32)
    cm = jnp.asarray(rng.randn(b, l, g, sd), jnp.float32)
    d = jnp.asarray(rng.randn(h), jnp.float32)
    return x, dt, a, bm, cm, d


@pytest.mark.parametrize("chunk", [8, 32, 128])
def test_chunked_matches_scan(chunk, rng):
    args = rand_inputs(rng)
    want = S.ssd_scan_ref(*args)
    got = S.ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), l=st.sampled_from([17, 64, 100]),
       chunk=st.sampled_from([16, 64]))
def test_chunked_matches_scan_property(seed, l, chunk):
    rng = np.random.RandomState(seed)
    args = rand_inputs(rng, l=l)
    want = S.ssd_scan_ref(*args)
    got = S.ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=2e-3)


def test_mamba_block_decode_parity(rng):
    spec = SSMSpec(state_dim=8, head_dim=16, expand=2, conv_width=4,
                   chunk_size=8, num_groups=1)
    dm, l = 32, 20
    params = S.init_mamba(jax.random.PRNGKey(1), dm, spec, dtype=jnp.float32)
    xs = jnp.asarray(rng.randn(2, l, dm) * 0.3, jnp.float32)
    full = S.mamba_block(params, xs, spec, chunk=8)
    cache = S.init_mamba_cache(dm, spec, 2, dtype=jnp.float32)
    outs = []
    for t in range(l):
        y, cache = S.mamba_decode(params, xs[:, t:t + 1], cache, spec)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               atol=1e-3, rtol=1e-3)


def test_state_decay_contracts(rng):
    """|exp(dt*a)| < 1: the recurrence is stable (decay contract)."""
    _, dt, a, *_ = rand_inputs(rng)
    decay = jnp.exp(dt * a)
    assert float(decay.max()) < 1.0
    assert float(decay.min()) > 0.0


def test_grads_flow_through_chunked(rng):
    args = rand_inputs(rng, b=1, l=32)
    def loss(x):
        return jnp.sum(S.ssd_chunked(x, *args[1:], chunk=16) ** 2)
    g = jax.grad(loss)(args[0])
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
