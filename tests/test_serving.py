"""Serving engine: continuous batching correctness + ring memory claims.

The load-bearing properties of the device-resident engine:
  * batched padded prefill + scan decode == per-sequence greedy reference,
  * scan decode == stepwise decode token-for-token (same RNG stream),
  * per-slot temperature is respected (the seed engine hard-coded 0.0),
  * chunked prefill == single-shot prefill across a ring wrap,
  * slot eviction/reuse under more requests than slots.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, with_swat
from repro.core import model as Mod
from repro.serving.engine import Request, ServingEngine, ring_cache_bytes
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def swat_setup():
    cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n, max_len=256):
    """Decode one sequence with plain prefill+decode calls."""
    logits, caches = Mod.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]}, max_len=max_len)
    toks = [int(jnp.argmax(logits[0, 0]))]
    for _ in range(n - 1):
        logits, caches = Mod.decode_step(
            params, cfg, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            caches)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def test_engine_matches_reference(setup):
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)
               for _ in range(3)]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=256)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    results = engine.run(reqs)
    assert len(results) == 3
    for r, p in zip(results, prompts):
        want = greedy_reference(cfg, params, p, 6)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_mixed_length_batched_prefill(setup):
    """One padded, batched prefill over prompts of different lengths must
    reproduce each per-sequence reference (lengths mask the padding)."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    lens = (5, 23, 12)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    engine = ServingEngine(cfg, params, batch_slots=3, max_len=256,
                           batch_prefill=True)
    results = engine.run([Request(rid=i, prompt=p, max_new_tokens=5)
                          for i, p in enumerate(prompts)])
    for r, p in zip(results, prompts):
        want = greedy_reference(cfg, params, p, 5)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_slot_eviction_and_reuse(setup):
    """More requests than slots: finished sequences release their slot and
    the next pending prompt prefills into it, mid-decode for the others."""
    cfg, params = setup
    rng = np.random.RandomState(1)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=128)
    reqs = [Request(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, (8 + i,)).astype(np.int32),
        max_new_tokens=3 + (i % 3)) for i in range(7)]
    results = engine.run(reqs)
    assert [r.rid for r in results] == list(range(7))
    for i, r in enumerate(results):
        assert len(r.tokens) == 3 + (i % 3)
        want = greedy_reference(cfg, params, reqs[i].prompt, 3 + (i % 3),
                                max_len=128)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_scan_decode_equals_stepwise(swat_setup):
    """scan_steps=N must be token-for-token identical to the per-token-sync
    path, including temperature>0 slots and slot refills: blocks stop at the
    earliest completion, so the RNG stream (one split per executed step) is
    the same for every scan_steps."""
    cfg, params = swat_setup
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (12, 30, 7, 18, 25, 10)]
    budgets = [6, 9, 4, 7, 5, 8]
    temps = [0.0, 2.0, 0.0, 3.0, 1.0, 0.0]

    def mkreqs():
        return [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                        temperature=temps[i]) for i in range(6)]

    out = {}
    for steps in (1, 8):
        eng = ServingEngine(cfg, params, batch_slots=3, max_len=128,
                            scan_steps=steps, seed=42)
        out[steps] = {r.rid: r.tokens for r in eng.run(mkreqs())}
    assert out[1] == out[8], (out[1], out[8])


def test_temperature_respected(swat_setup):
    """Regression for the seed engine passing 0.0 instead of the request
    temperature: a temperature>0 request must actually sample (differ from
    greedy) and be reproducible under a fixed engine seed."""
    cfg, params = swat_setup
    rng = np.random.RandomState(4)
    prompt = rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)

    def run_once(temp, seed=7):
        eng = ServingEngine(cfg, params, batch_slots=1, max_len=128,
                            seed=seed)
        return eng.run([Request(rid=0, prompt=prompt, max_new_tokens=12,
                                temperature=temp)])[0].tokens

    greedy = run_once(0.0)
    hot1 = run_once(5.0)
    hot2 = run_once(5.0)
    assert hot1 == hot2, "fixed seed must reproduce"
    assert hot1 != greedy, "temperature>0 must actually sample"


def test_chunked_prefill_equals_single_shot(swat_setup):
    """Sequence-chunked prefill (bounded VMEM) is exact: same tokens as
    single-shot prefill, including prompts long enough to wrap the ring
    (window=16, cap=21 < prompt 40)."""
    cfg, params = swat_setup
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (40, 9, 33)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    base = ServingEngine(cfg, params, batch_slots=2, max_len=256)
    chunked = ServingEngine(cfg, params, batch_slots=2, max_len=256,
                            prefill_chunk=8)
    want = {r.rid: r.tokens for r in base.run(list(reqs))}
    got = {r.rid: r.tokens for r in chunked.run(list(reqs))}
    assert got == want
    for rid, toks in got.items():
        assert toks == greedy_reference(cfg, params, prompts[rid], 5)


def test_prompt_longer_than_max_len_not_truncated(swat_setup):
    """Regression: prompts longer than max_len must NOT be head-truncated —
    the ring prefill keeps exactly what the full-prompt reference keeps
    (last window + pinned globals), so generation still conditions on the
    most recent context."""
    cfg, params = swat_setup
    rng = np.random.RandomState(9)
    prompt = rng.randint(0, cfg.vocab_size, (100,)).astype(np.int32)
    want = greedy_reference(cfg, params, prompt, 5, max_len=64)
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64)
    got = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert got[0].tokens == want, (got[0].tokens, want)


def test_moe_batched_prefill_matches_reference():
    """Padding must not leak through MoE dispatch: serving uses the
    capacity-free combine, so a row's tokens are independent of its
    batch-mates."""
    cfg = get_smoke_config("granite_moe_1b")
    params = Mod.init_model(jax.random.PRNGKey(1), cfg)
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (14, 6)]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    res = eng.run([Request(rid=i, prompt=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
    for r, p in zip(res, prompts):
        assert r.tokens == greedy_reference(cfg, params, p, 4, max_len=64)


def test_scheduler_packs_and_respects_budget():
    pending = collections.deque(
        Request(rid=i, prompt=np.zeros((l,), np.int32))
        for i, l in enumerate((30, 10, 50, 8)))
    sched = Scheduler(max_prefill_tokens=96, pad_to=16)
    plan = sched.plan(pending, num_free=4)
    # 30->pad 32; +10 -> pad stays 32 (2x32=64 <= 96); +50 would need
    # 3x64=192 > 96 -> stop at two
    assert [r.rid for r in plan.requests] == [0, 1]
    assert plan.tokens.shape == (2, 32)
    assert plan.lengths.tolist() == [30, 10]
    assert len(pending) == 2
    # always admits at least one even when it alone exceeds the budget
    plan2 = sched.plan(pending, num_free=1)
    assert [r.rid for r in plan2.requests] == [2]
    assert plan2.tokens.shape[1] == 64


def test_sampling_isolated_across_slots(swat_setup):
    """sampling.sample consumes IDENTICAL randomness for greedy and sampled
    rows (one categorical draw over the whole batch, masked afterwards), so
    flipping one slot's temperature must not perturb any other slot's
    tokens. The scan==stepwise guarantee silently relies on this: if a
    greedy row skipped the draw, admission order would shift every later
    row's RNG stream."""
    cfg, params = swat_setup
    rng = np.random.RandomState(8)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (11, 19, 14)]

    def run_with(temps):
        eng = ServingEngine(cfg, params, batch_slots=3, max_len=128,
                            scan_steps=4, seed=13)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=6,
                        temperature=temps[i]) for i in range(3)]
        return {r.rid: r.tokens for r in eng.run(reqs)}

    cold = run_with([0.0, 0.0, 0.0])
    hot = run_with([0.0, 4.0, 0.0])     # swap ONLY slot 1 to sampling
    assert hot[0] == cold[0], (hot[0], cold[0])
    assert hot[2] == cold[2], (hot[2], cold[2])
    assert hot[1] != cold[1], "temperature=4 slot must actually sample"

    # and at the sampling level: the greedy rows' argmax is untouched by
    # the batch-wide draw whatever the temperature vector is
    key = jax.random.PRNGKey(5)
    logits = jnp.asarray(rng.randn(3, cfg.vocab_size), jnp.float32)
    from repro.serving import sampling
    a = sampling.sample(key, logits, jnp.asarray([0.0, 0.0, 0.0]))
    b = sampling.sample(key, logits, jnp.asarray([0.0, 4.0, 0.0]))
    assert a[0] == b[0] and a[2] == b[2]


def test_request_prompt_shape_normalized(setup):
    """Regression: a (1, L) or list-of-lists prompt used to measure
    longest=1 in Scheduler.plan and crash (or mis-pad) at batch fill. Any
    spelling must serve identically to the flat (L,) prompt."""
    cfg, params = setup
    rng = np.random.RandomState(7)
    flat = rng.randint(0, cfg.vocab_size, (13,)).astype(np.int32)
    want = ServingEngine(cfg, params, batch_slots=1, max_len=128).run(
        [Request(rid=0, prompt=flat, max_new_tokens=4)])[0].tokens
    for spelling in (flat[None, :],                 # (1, L)
                     [list(map(int, flat))],        # list-of-lists
                     list(map(int, flat))):         # plain list
        got = ServingEngine(cfg, params, batch_slots=1, max_len=128).run(
            [Request(rid=0, prompt=spelling, max_new_tokens=4)])[0].tokens
        assert got == want, (spelling, got, want)


def test_scheduler_slot_quantum_trims_to_multiple():
    """Divisibility-aware admission: with a slot quantum (the mesh slot-axis
    size) the batch is trimmed to a quantum multiple when MORE than one
    quantum is available — the remainder stays queued, FCFS order intact —
    but a final sub-quantum batch still admits."""
    def mkpending(n):
        return collections.deque(
            Request(rid=i, prompt=np.zeros((8,), np.int32)) for i in range(n))

    sched = Scheduler(max_prefill_tokens=8192, pad_to=16, slot_quantum=2)
    pending = mkpending(3)
    plan = sched.plan(pending, num_free=4)
    assert [r.rid for r in plan.requests] == [0, 1]   # trimmed 3 -> 2
    assert [r.rid for r in pending] == [2]
    plan2 = sched.plan(pending, num_free=4)
    assert [r.rid for r in plan2.requests] == [2]     # tail still admits


def test_empty_prompt_rejected(setup):
    """An empty prompt is a malformed REQUEST, not a malformed batch: it
    finalizes as status 'rejected' (with the reason in `reason`) and the
    rest of the batch serves normally. The seed raised ValueError out of
    `run()`, destroying every co-batched request."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64)
    out = eng.run([Request(rid=0, prompt=np.zeros((0,), np.int32)),
                   Request(rid=1, prompt=np.arange(4, dtype=np.int32) + 1,
                           max_new_tokens=4)])
    by = {r.rid: r for r in out}
    assert by[0].status == "rejected" and by[0].tokens == []
    assert "empty prompt" in by[0].reason
    assert by[1].status == "ok" and len(by[1].tokens) == 4
    from repro.serving import faults as F
    F.consume_events()


def test_allocation_rounding_does_not_widen_window(swat_setup):
    """The tile-rounded cache ALLOCATION (layers.cache_allocation — lets
    swat_decode tile the ring with no per-token pad copy) must not change
    what decode attends to: window=16,g=4 => logical capacity 21. With
    max_len=21 the allocation is clamped to exactly 21 rows; with
    max_len=256 it is rounded to 32 — eleven zero tail rows. Tokens must be
    identical in both, across a ring wrap: the rotation modulus and the
    valid-prefix mask stay at the LOGICAL capacity."""
    cfg, params = swat_setup
    from repro.core.layers import cache_allocation, cache_capacity
    from repro.core.model import attn_cfg
    acfg = attn_cfg(cfg, "attn")
    assert cache_capacity(acfg, 256) == 21
    assert cache_allocation(acfg, 256) == 32      # rounded tail
    assert cache_allocation(acfg, 21) == 21       # clamped: no tail
    rng = np.random.RandomState(10)
    prompt = rng.randint(0, cfg.vocab_size, (40,)).astype(np.int32)
    out = {}
    for max_len in (21, 256):
        eng = ServingEngine(cfg, params, batch_slots=1, max_len=max_len)
        out[max_len] = eng.run(
            [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].tokens
    assert out[21] == out[256], out


def test_tokens_per_step_lookahead_token_identical(swat_setup):
    """tokens_per_step=T allocates T-1 extra ring rows per cache (the
    speculative-decode hook) — and the generated tokens must be IDENTICAL
    to the tps=1 engine: the positional window mask hides the extra ring
    depth (without it, decode on the wider ring would attend one stale
    token and every output would drift). Exercises the ISSUE-3 window
    bugfix end-to-end through prefill, chunked or not, and scan decode."""
    cfg, params = swat_setup
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (40, 9, 26)]

    def run(tps, **kw):
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=256,
                            scan_steps=4, seed=5, tokens_per_step=tps, **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6,
                        temperature=[0.0, 2.0, 0.0][i])
                for i, p in enumerate(prompts)]
        return {r.rid: r.tokens for r in eng.run(reqs)}

    base = run(1)
    assert run(4) == base
    assert run(4, prefill_chunk=8) == base


def test_decode_step_multi_token_matches_loop(swat_setup):
    """model.decode_step with T=4 tokens == 4 sequential T=1 steps (logits
    and caches): the multi-query primitive the speculative-decode verify
    loop needs, exact because the lookahead ring keeps every query's window
    resident through the step's own inserts."""
    cfg, params = swat_setup
    rng = np.random.RandomState(12)
    prompt = rng.randint(0, cfg.vocab_size, (20,)).astype(np.int32)
    t = 4
    _, caches = Mod.prefill(params, cfg, {"tokens": jnp.asarray(prompt)[None]},
                            max_len=128, lookahead=t - 1)
    toks = rng.randint(0, cfg.vocab_size, (1, t)).astype(np.int32)
    multi, mcaches = Mod.decode_step(params, cfg,
                                     {"tokens": jnp.asarray(toks)}, caches,
                                     lookahead=t - 1)
    seq_logits = []
    for j in range(t):
        lg, caches = Mod.decode_step(params, cfg,
                                     {"tokens": jnp.asarray(toks[:, j:j + 1])},
                                     caches, lookahead=t - 1)
        seq_logits.append(lg)
    np.testing.assert_allclose(np.asarray(multi),
                               np.asarray(jnp.concatenate(seq_logits, 1)),
                               atol=1e-4, rtol=1e-4)
    for la, lb in zip(jax.tree.leaves(mcaches), jax.tree.leaves(caches)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   atol=1e-6, rtol=1e-6)


def test_engine_pallas_decode_impl_serves(swat_setup):
    """decode_impl="pallas" (the fused swat_decode kernel, interpret mode on
    CPU) serves every request to its exact budget and matches the ref-impl
    engine greedily: same masks, kernel-accumulated numerics."""
    cfg, params = swat_setup
    rng = np.random.RandomState(13)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (12, 25)]

    def run(impl):
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                            scan_steps=4, seed=3, decode_impl=impl)
        return {r.rid: r.tokens
                for r in eng.run([Request(rid=i, prompt=p, max_new_tokens=5)
                                  for i, p in enumerate(prompts)])}

    assert run("pallas") == run("ref")


def test_ring_cache_linear_memory():
    """Paper Fig. 3: dense decode memory grows with context; SWAT's ring
    stays flat at O(window)."""
    dense = get_config("llama3p2_1b")
    swat = with_swat(dense, window=2048, num_global=0)
    b = 8
    dense_16k = ring_cache_bytes(dense, b, 16384)
    dense_64k = ring_cache_bytes(dense, b, 65536)
    swat_16k = ring_cache_bytes(swat, b, 16384)
    swat_64k = ring_cache_bytes(swat, b, 65536)
    assert dense_64k == 4 * dense_16k
    assert swat_64k == swat_16k            # flat
    assert swat_16k < dense_16k / 4


def test_mamba_state_is_constant_memory():
    cfg = get_config("mamba2_1p3b")
    assert ring_cache_bytes(cfg, 1, 16384) == ring_cache_bytes(cfg, 1, 524288)


# ---------------------------------------------------------------------------
# Scheduler / engine edge cases (ISSUE-6 hardening sweep)
# ---------------------------------------------------------------------------

def test_prompt_exactly_max_len_minus_budget(swat_setup):
    """Prompt length + budget lands EXACTLY on max_len: the last decode
    step inserts at ring position max_len-1 (the final legal row). Tokens
    must match the reference — no off-by-one truncation, clamp, or wrap
    at the boundary."""
    cfg, params = swat_setup
    rng = np.random.RandomState(14)
    max_len, budget = 32, 8
    prompt = rng.randint(0, cfg.vocab_size,
                         (max_len - budget,)).astype(np.int32)
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=max_len)
    got = eng.run([Request(rid=0, prompt=prompt,
                           max_new_tokens=budget)])[0].tokens
    assert len(got) == budget
    assert got == greedy_reference(cfg, params, prompt, budget,
                                   max_len=max_len)


def test_all_slots_done_mid_block(swat_setup):
    """A decode block longer than every live budget: slots go inactive
    mid-scan, the dead steps' emissions are masked, budgets never go
    negative, and the tokens are exactly the budget-sized prefix of the
    normal run. (run() sizes blocks to stop at the earliest completion;
    calling _decode_block directly is the only way to force the
    all-done-mid-block path the scan's `active` flags guard.)"""
    cfg, params = swat_setup
    rng = np.random.RandomState(15)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (12, 19)]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                        scan_steps=8, seed=21)
    eng._admit(collections.deque(
        Request(rid=i, prompt=p, max_new_tokens=3)
        for i, p in enumerate(prompts)))
    done = eng._decode_block(8)          # 8 steps vs budgets of 3
    got = {r.rid: r.tokens for r in done}
    assert sorted(got) == [0, 1]
    assert all(b == 0 for b in eng.slot_budget[:2])
    for i, p in enumerate(prompts):
        assert got[i] == greedy_reference(cfg, params, p, 3, max_len=128)
    assert eng.step() == []              # drained: empty result, no crash
    assert eng._decode_block(4) == []


def test_single_pending_request_admits_under_quantum():
    """slot_quantum > pending: one lone request must still admit (the
    sub-quantum final-batch rule) — immediately, not after waiting for a
    full quantum that will never arrive."""
    sched = Scheduler(max_prefill_tokens=8192, pad_to=16, slot_quantum=4)
    pending = collections.deque(
        [Request(rid=0, prompt=np.zeros((8,), np.int32))])
    plan = sched.plan(pending, num_free=4)
    assert plan is not None and [r.rid for r in plan.requests] == [0]
    assert not pending
    assert sched.plan(pending, num_free=4) is None   # drained queue


def test_step_after_drain_is_empty(swat_setup):
    """step() on a fully drained engine: empty result, no state change,
    repeatable — the serving loop's idle path."""
    cfg, params = swat_setup
    rng = np.random.RandomState(16)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    res = eng.run([Request(
        rid=0, prompt=rng.randint(0, cfg.vocab_size, (9,)).astype(np.int32),
        max_new_tokens=4)])
    assert len(res) == 1 and len(res[0].tokens) == 4
    assert all(eng.slot_free)
    budgets = eng.slot_budget.copy()
    for _ in range(3):
        assert eng.step() == []
    assert (eng.slot_budget == budgets).all()


def test_sample_determinism_across_batch_and_topk():
    """sampling.sample across batch sizes x top_k (the ISSUE-6 fix test):
    temperature<=0 rows are bitwise the raw-logits argmax at EVERY top_k
    (top-k truncation must not touch the greedy path), one slot's
    temperature never perturbs any other slot at any top_k (the draw's
    randomness is shape-dependent only), and a fixed key reproduces."""
    from repro.serving import sampling
    rng = np.random.RandomState(17)
    v = 64
    for b in (1, 2, 5, 8):
        logits = jnp.asarray(rng.randn(b, v), jnp.float32)
        want_greedy = np.asarray(jnp.argmax(logits, axis=-1))
        key = jax.random.PRNGKey(31 + b)
        for top_k in (0, 1, 4, v, v + 9):
            cold = np.asarray(sampling.sample(
                key, logits, jnp.zeros((b,)), top_k=top_k))
            assert (cold == want_greedy).all(), (b, top_k)
            again = np.asarray(sampling.sample(
                key, logits, jnp.zeros((b,)), top_k=top_k))
            assert (cold == again).all(), (b, top_k)
            for j in range(b):           # heat ONE slot at a time
                temps = np.zeros((b,), np.float32)
                temps[j] = 3.0
                hot = np.asarray(sampling.sample(
                    key, logits, jnp.asarray(temps), top_k=top_k))
                others = np.arange(b) != j
                assert (hot[others] == cold[others]).all(), (b, top_k, j)
            # top_k=1 sampling degenerates to greedy even when hot
            if top_k == 1:
                hot_all = np.asarray(sampling.sample(
                    key, logits, jnp.full((b,), 2.0), top_k=1))
                assert (hot_all == want_greedy).all(), b
