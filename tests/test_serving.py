"""Serving engine: continuous batching correctness + ring memory claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, with_swat
from repro.core import model as Mod
from repro.serving.engine import Request, ServingEngine, ring_cache_bytes


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n):
    """Decode one sequence with plain prefill+decode calls."""
    logits, caches = Mod.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]}, max_len=256)
    toks = [int(jnp.argmax(logits[0, 0]))]
    for _ in range(n - 1):
        logits, caches = Mod.decode_step(
            params, cfg, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            caches)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def test_engine_matches_reference(setup):
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)
               for _ in range(3)]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=256)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    results = engine.run(reqs)
    assert len(results) == 3
    for r, p in zip(results, prompts):
        want = greedy_reference(cfg, params, p, 6)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_slot_reuse(setup):
    cfg, params = setup
    rng = np.random.RandomState(1)
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=128)
    reqs = [Request(rid=i, prompt=rng.randint(
        0, cfg.vocab_size, (8,)).astype(np.int32), max_new_tokens=3)
        for i in range(3)]
    results = engine.run(reqs)      # 3 requests through 1 slot
    assert [r.rid for r in results] == [0, 1, 2]
    assert all(len(r.tokens) == 3 for r in results)


def test_ring_cache_linear_memory():
    """Paper Fig. 3: dense decode memory grows with context; SWAT's ring
    stays flat at O(window)."""
    dense = get_config("llama3p2_1b")
    swat = with_swat(dense, window=2048, num_global=0)
    b = 8
    dense_16k = ring_cache_bytes(dense, b, 16384)
    dense_64k = ring_cache_bytes(dense, b, 65536)
    swat_16k = ring_cache_bytes(swat, b, 16384)
    swat_64k = ring_cache_bytes(swat, b, 65536)
    assert dense_64k == 4 * dense_16k
    assert swat_64k == swat_16k            # flat
    assert swat_16k < dense_16k / 4


def test_mamba_state_is_constant_memory():
    cfg = get_config("mamba2_1p3b")
    assert ring_cache_bytes(cfg, 1, 16384) == ring_cache_bytes(cfg, 1, 524288)
