"""Deterministic stand-in for `hypothesis` so tier-1 collects in a bare env.

Provides the tiny subset this suite uses — `given`, `settings`, and the
strategies `integers / booleans / sampled_from / just / builds` — backed by a
numpy RandomState seeded from the test's qualified name. Every run draws the
same examples in the same order: a failure reproduces exactly, which is all
the property tests here need (they sweep seeds/shapes, not adversarial
shrinking). When the real hypothesis is installed the test modules import it
instead and this file is inert.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.RandomState):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: int(r.randint(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: bool(r.randint(0, 2)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda r: opts[r.randint(0, len(opts))])

    @staticmethod
    def just(value):
        return _Strategy(lambda r: value)

    @staticmethod
    def builds(target, *arg_strats, **kw_strats):
        return _Strategy(lambda r: target(
            *(s.example(r) for s in arg_strats),
            **{k: s.example(r) for k, s in kw_strats.items()}))


def given(**strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.RandomState(seed & 0x7FFFFFFF)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest resolves fixtures from the signature: hide the drawn params
        # (and the __wrapped__ attr functools.wraps added, which pytest
        # follows back to the original signature).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper.__dict__.pop("__wrapped__", None)
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


st = strategies
