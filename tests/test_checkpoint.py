"""Checkpoint manager: roundtrip, atomicity, retention, async, elasticity."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(4, 8), jnp.float32),
            "b": {"c": jnp.asarray(rng.randn(3), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}


def assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    t = tree()
    m.save(10, t, blocking=True)
    assert m.latest_step() == 10
    got = m.restore(10, like=jax.tree.map(jnp.zeros_like, t))
    assert_tree_equal(t, got)


def test_async_save_and_wait(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    for s in (1, 2, 3):
        m.save(s, tree(s))
    m.wait()
    assert m.all_steps() == [1, 2, 3]


def test_retention_gc(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, tree(s), blocking=True)
    assert m.all_steps() == [3, 4]


def test_partial_checkpoint_ignored(tmp_path):
    """A crash mid-write must not poison resume: dirs without a manifest are
    invisible; .tmp dirs are invisible."""
    m = CheckpointManager(tmp_path, keep=3)
    m.save(5, tree(), blocking=True)
    # simulate a crashed write
    (tmp_path / "step_00000009.tmp").mkdir()
    broken = tmp_path / "step_00000007"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    assert m.latest_step() == 5


def test_corrupt_manifest_rejected(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    m.save(5, tree(), blocking=True)
    t = tree()
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros(3),
                                         "step": jnp.zeros((), jnp.int32)}}
    with pytest.raises(AssertionError):
        m.restore(5, like=bad)  # shape mismatch detected


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved 'on one mesh' restores with different shardings
    (here: different target dtypes/placements via device_put path)."""
    m = CheckpointManager(tmp_path, keep=1)
    t = tree()
    m.save(1, t, blocking=True)
    sharding = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    got = m.restore(1, like=t, sharding=sharding)
    assert_tree_equal(t, got)
    for leaf in jax.tree.leaves(got):
        assert isinstance(leaf.sharding, jax.sharding.SingleDeviceSharding)


def test_overwrite_same_step(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    m.save(1, tree(0), blocking=True)
    m.save(1, tree(1), blocking=True)
    got = m.restore(1, like=tree(0))
    assert_tree_equal(tree(1), got)
