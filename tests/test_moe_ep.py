"""Expert-parallel MoE (explicit all-to-all) vs the dense oracle.

The EP schedule must compute the same function as moe_ffn_dense_ref when
capacity is generous (no drops), shard-count included in the check (4
devices, experts 8/4 = 2 per shard). Gradients flow through both
all_to_alls (shard_map transposes them)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import moe as M
    from repro.core import moe_ep as EP
    from repro.core.types import MoESpec

    mesh = jax.make_mesh((4,), ("model",))
    spec = MoESpec(num_experts=8, top_k=2)
    p = M.init_moe(jax.random.PRNGKey(0), 32, 64, spec, dtype=jnp.float32)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 32),
                    jnp.float32) * 0.5
"""


@pytest.mark.slow
def test_ep_matches_dense_ref_no_drops():
    run_sub(COMMON + """
    ref = M.moe_ffn_dense_ref(p, x, spec)
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        out, aux = EP.moe_ffn_ep(p, x, spec, mesh=mesh, axis="model",
                                 capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert np.isfinite(float(aux))
    print("ok", float(aux))
    """)


@pytest.mark.slow
def test_ep_matches_sort_dispatch_aux():
    """aux (load-balance statistic) must agree with the single-pass value."""
    run_sub(COMMON + """
    _, aux_ref = M.moe_ffn(p, x, spec, capacity_factor=8.0)
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        _, aux_ep = EP.moe_ffn_ep(p, x, spec, mesh=mesh, axis="model",
                                  capacity_factor=8.0)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-5)
    print("ok")
    """)


@pytest.mark.slow
def test_ep_gradients_flow():
    run_sub(COMMON + """
    def loss_ep(p, x):
        out, aux = EP.moe_ffn_ep(p, x, spec, mesh=mesh, axis="model",
                                 capacity_factor=8.0)
        return jnp.sum(out.astype(jnp.float32) ** 2) + 0.01 * aux

    def loss_ref(p, x):
        out = M.moe_ffn_dense_ref(p, x, spec)
        _, aux = M.moe_ffn(p, x, spec, capacity_factor=8.0)
        return jnp.sum(out.astype(jnp.float32) ** 2) + 0.01 * aux

    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        g_ep = jax.grad(loss_ep)(p, x)
    g_ref = jax.grad(loss_ref)(p, x)
    for k_ in ("w1", "w2", "w3", "router"):
        np.testing.assert_allclose(np.asarray(g_ep[k_]),
                                   np.asarray(g_ref[k_]),
                                   atol=5e-3, rtol=5e-3, err_msg=k_)
    print("ok")
    """)


def test_ep_wire_bytes_independent_of_global_batch():
    from repro.core.moe_ep import ep_wire_bytes_per_device
    # doubling global batch with fixed local tokens leaves wire bytes fixed
    a = ep_wire_bytes_per_device(4096, 8, 1024)
    assert a == ep_wire_bytes_per_device(4096, 8, 1024)
    assert a == 2 * 4096 * 8 * 1.25 * 1024 * 2
