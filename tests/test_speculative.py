"""Speculative decoding: greedy output must be BITWISE sequential decode.

The engine's draft/verify/accept loop emits 1..k+1 tokens per step, but
every emitted token is the model's own output for a fully verified prefix
— so for greedy requests the speculative engine is token-for-token the
`speculative=0` sequential engine, on every attention-bearing config
(dense, SWAT window+global, gemma2's local/global alternation, GQA), on
both decode impls, and at every scan_steps. That identity is THE
acceptance bar for shipping speculation; everything else here (rollback
state, drafter behavior, telemetry arithmetic, budget clamping) guards
the machinery that makes it hold.

The sharded counterpart (4-device slot-parallel mesh, subprocess) lives
in tests/test_serving_sharded.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, with_swat
from repro.core import model as Mod
from repro.core.layers import cache_capacity
from repro.serving.drafter import NGramDrafter, get_drafter
from repro.serving.engine import Request, ServingEngine


def _build(name, swat=False):
    cfg = get_smoke_config(name)
    if swat:
        cfg = with_swat(cfg, window=16, num_global=4)
    return cfg, Mod.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def models():
    """Every attention-bearing smoke family the engine serves:
    llama+swat (window+global ring, GQA: 4 q heads on 1 kv head),
    gemma2 (local/global alternation + logit softcap), llama dense."""
    return {
        "llama_swat": _build("llama3p2_1b", swat=True),
        "gemma2": _build("gemma2_2b"),
        "llama_dense": _build("llama3p2_1b"),
    }


def _requests(cfg, rng, temps=None):
    lens = (12, 30, 7, 18, 25, 10)
    budgets = (6, 19, 1, 27, 5, 2)       # incl. prefill-only and clamp-y
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    temps = temps or [0.0] * len(lens)
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                    temperature=temps[i]) for i in range(len(lens))]


def _run(cfg, params, reqs, **kw):
    kw.setdefault("batch_slots", 4)
    kw.setdefault("max_len", 128)
    kw.setdefault("scan_steps", 4)
    kw.setdefault("seed", 11)
    eng = ServingEngine(cfg, params, **kw)
    return eng, {r.rid: r.tokens for r in eng.run(reqs)}


# ------------------------------------------------------------- identity --
@pytest.mark.parametrize("name", ["llama_swat", "gemma2", "llama_dense"])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_greedy_identity(models, name, impl):
    """Greedy speculative == sequential, bitwise, per config x impl —
    mixed prompt lengths, slot eviction/refill (6 reqs on 4 slots), and
    budgets that exercise the per-slot clamp (1 and 2 left after
    prefill's token with k=3 drafts in flight)."""
    cfg, params = models[name]
    rng = np.random.RandomState(3)
    reqs = _requests(cfg, rng)
    _, base = _run(cfg, params, reqs, decode_impl=impl)
    eng, spec = _run(cfg, params, reqs, decode_impl=impl, speculative=3)
    assert base == spec, (name, impl, base, spec)
    # telemetry arithmetic: decode emitted everything but prefill's token
    total = sum(len(t) for t in spec.values())
    assert eng.stats["tokens_emitted"] == total - len(reqs)
    assert 0 <= eng.stats["draft_accepted"] <= eng.stats["draft_proposed"]


def test_identity_across_scan_steps_and_k(models):
    """The block size and the draft depth are performance knobs only:
    greedy tokens are invariant across scan_steps x speculative."""
    cfg, params = models["llama_swat"]
    rng = np.random.RandomState(5)
    reqs = _requests(cfg, rng)
    _, want = _run(cfg, params, reqs)
    for steps in (1, 4, 8):
        for k in (1, 2, 5):
            _, got = _run(cfg, params, reqs, scan_steps=steps, speculative=k)
            assert got == want, (steps, k, got, want)


def test_greedy_rows_exact_under_mixed_temperatures(models):
    """Sampled slots share the batch with greedy slots: the greedy rows
    must still be bitwise sequential (verification is row-local), sampled
    rows serve to exact budget, and the speculative engine is
    bit-reproducible run-to-run (same seed => same tokens)."""
    cfg, params = models["gemma2"]
    rng = np.random.RandomState(7)
    temps = [0.0, 1.5, 0.0, 2.5, 1.0, 0.0]
    reqs = _requests(cfg, rng, temps=temps)
    _, base = _run(cfg, params, reqs)
    _, spec = _run(cfg, params, reqs, speculative=3)
    for i, t in enumerate(temps):
        assert len(spec[i]) == len(base[i])
        if t == 0.0:
            assert spec[i] == base[i], (i, spec[i], base[i])
    _, again = _run(cfg, params, reqs, speculative=3)
    assert spec == again


def test_greedy_identity_with_top_k(models):
    """Engine-level top_k truncates the SAMPLING path only; greedy rows
    argmax the raw logits, so speculative identity must survive top_k."""
    cfg, params = models["llama_swat"]
    rng = np.random.RandomState(9)
    reqs = _requests(cfg, rng)
    _, base = _run(cfg, params, reqs, top_k=4)
    _, spec = _run(cfg, params, reqs, top_k=4, speculative=3)
    assert base == spec


def test_step_api_speculative(models):
    """`step()` (the per-block serving entry point) works speculatively:
    each call emits >= 1 token per live slot, budgets never overshoot."""
    cfg, params = models["llama_swat"]
    rng = np.random.RandomState(13)
    reqs = _requests(cfg, rng)[:4]
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=128,
                        speculative=3, seed=11)
    eng._admit(__import__("collections").deque(reqs))
    done = list(eng._completed)
    while not all(eng.slot_free):
        done.extend(eng.step())
        assert all(b >= 0 for b in eng.slot_budget)
    got = {r.rid: r.tokens for r in done}
    _, want = _run(cfg, params, reqs)
    assert got == want
    assert eng.step() == []          # drained engine: empty, no crash


# ------------------------------------------------------------- rollback --
def test_rollback_restores_sequential_ring_state(models):
    """The spec engine's ring write pointers obey the sequential-state
    invariant: a slot that consumed its prompt (L) and emitted k tokens
    holds step == L + k - 1 (the newest token is pending, not yet fed).
    The rollback must subtract exactly the rejected rows every step,
    ragged per slot, in every layer, for this to hold at the end — and
    inactive slots must restore their pointer exactly (e=0 -> step
    unchanged), which is why retired slots stay on the formula too.
    (The sequential engine itself does NOT satisfy this at run end: it
    keeps advancing retired slots' dead pointers inside a block. Only
    live-slot state is ever read, so only the formula matters.)"""
    cfg, params = models["llama_swat"]
    rng = np.random.RandomState(17)
    lens = (12, 30, 7, 18)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in lens]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=b)
            for i, (p, b) in enumerate(zip(prompts, (6, 19, 4, 27)))]
    # 4 requests on 4 slots: request i lives (and dies) in slot i
    eng, out = _run(cfg, params, reqs, speculative=3)
    for lname, c in eng.caches.items():
        step = np.asarray(c["step"])            # (super_blocks, slots)
        for s in range(4):
            want = lens[s] + len(out[s]) - 1
            assert (step[:, s] == want).all(), (lname, s, step[:, s], want)


def test_unsupported_config_is_rejected():
    """speculative= on a rollback-unsafe config (mamba state) must fail
    loudly at construction, not corrupt state at decode time."""
    import dataclasses
    cfg = get_smoke_config("llama3p2_1b")
    mamba_like = dataclasses.replace(cfg, layer_pattern=("mamba",))
    assert not Mod.speculative_supported(mamba_like)
    with pytest.raises(AssertionError):
        ServingEngine(mamba_like, None, speculative=2)


def test_lookahead_rows_sized_for_drafts(models):
    """speculative=k forces tokens_per_step to k+1, which sizes the ring
    with k lookahead rows — the no-eviction guarantee the rollback proof
    leans on (cache_capacity = window + 1 + lookahead + globals)."""
    cfg, params = models["llama_swat"]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                        speculative=3)
    assert eng.tokens_per_step == 4
    acfg = Mod.attn_cfg(cfg, "attn")
    cap = cache_capacity(acfg, 128, lookahead=3)
    assert cap == acfg.spec.window + 1 + 3 + acfg.spec.num_global


# -------------------------------------------------------------- drafter --
def test_drafter_propose_finds_repeats():
    """propose() returns the continuation of the most recent longest
    suffix match; slots with no match repeat their last token."""
    d = NGramDrafter(max_ngram=3, history=16)
    hist, cnt = d.init_state(2)
    # slot 0: ... 5 6 7 8 5 6  -> context suffix (5, 6) matched at the
    # earlier occurrence, continuation 7 8 ...
    seq = [1, 2, 5, 6, 7, 8, 5, 6]
    hist[0], cnt[0] = d.seed_row(np.array(seq))
    # slot 1: no repeats at all
    hist[1], cnt[1] = d.seed_row(np.array([3, 9, 4, 11]))
    out = np.asarray(d.propose(jnp.asarray(hist), jnp.asarray(cnt), 3))
    assert out[0].tolist() == [7, 8, 5]
    assert out[1].tolist() == [11, 11, 11]


def test_drafter_prefers_recent_and_longer_matches():
    d = NGramDrafter(max_ngram=3, history=32)
    hist, cnt = d.init_state(2)
    # slot 0: suffix (2, 3) occurs twice — recency picks the LATER one
    hist[0], cnt[0] = d.seed_row(np.array([2, 3, 7, 7, 2, 3, 9, 9, 2, 3]))
    # slot 1: 1-gram match everywhere, but a full 3-gram match exists
    # earlier — length beats recency
    hist[1], cnt[1] = d.seed_row(np.array([5, 6, 7, 8, 1, 7, 2, 5, 6, 7]))
    out = np.asarray(d.propose(jnp.asarray(hist), jnp.asarray(cnt), 2))
    assert out[0].tolist() == [9, 9]
    assert out[1].tolist() == [8, 1]


def test_drafter_observe_matches_numpy_oracle():
    """observe() == append-then-keep-last-H, ragged per slot, including
    e=0 (untouched) and overflow past the history length."""
    d = NGramDrafter(history=8)
    rng = np.random.RandomState(23)
    hist = rng.randint(0, 50, (4, 8)).astype(np.int32)
    cnt = np.array([8, 3, 0, 6], np.int32)
    toks = rng.randint(0, 50, (4, 5)).astype(np.int32)
    e = np.array([5, 2, 0, 3], np.int32)
    nh, nc = d.observe(jnp.asarray(hist), jnp.asarray(cnt),
                       jnp.asarray(toks), jnp.asarray(e))
    nh, nc = np.asarray(nh), np.asarray(nc)
    for b in range(4):
        want = np.concatenate([hist[b], toks[b, :e[b]]])[-8:]
        assert nh[b].tolist() == want.tolist(), b
        assert nc[b] == min(cnt[b] + e[b], 8)


def test_drafter_seed_row_truncates_to_history():
    d = NGramDrafter(history=6)
    row, cnt = d.seed_row(np.arange(10))
    assert cnt == 6 and row.tolist() == [4, 5, 6, 7, 8, 9]
    row, cnt = d.seed_row(np.array([3, 1]))
    assert cnt == 2 and row.tolist() == [0, 0, 0, 0, 3, 1]


def test_drafter_is_compile_key():
    """Drafter specs are frozen/hashable and distinct specs are distinct
    engine compile keys (get_drafter normalizes None to the default)."""
    assert get_drafter(None) == NGramDrafter()
    assert hash(NGramDrafter(2, 32)) != hash(NGramDrafter(3, 32)) or \
        NGramDrafter(2, 32) != NGramDrafter(3, 32)
    with pytest.raises(AssertionError):
        get_drafter("not a drafter")


# ------------------------------------------------------------ telemetry --
def test_acceptance_rate_on_self_similar_output(models):
    """A drafter that proposes from the model's own history should land a
    healthy acceptance rate once greedy decode settles into its
    attractor — the mechanism the serve-bench speedup relies on. The
    bound is deliberately loose (it guards 'speculation does something',
    not a specific rate)."""
    cfg, params = models["gemma2"]
    rng = np.random.RandomState(29)
    prompts = [rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=48)
            for i, p in enumerate(prompts)]
    eng, _ = _run(cfg, params, reqs, speculative=3, scan_steps=8)
    assert eng.stats["draft_accepted"] > 0
    assert eng.acceptance_rate > 0.1, eng.stats
    # fresh engines start clean
    assert ServingEngine(cfg, params, batch_slots=2,
                         speculative=2).acceptance_rate == 0.0
