"""Pipeline parallelism: GPipe loss == single-pass loss, grads flow to every
stage, and the PP train step runs. Subprocess multi-device pattern."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.core import model as Mod
    from repro.distributed import pipeline as PP
    from repro.launch import mesh as mesh_lib

    cfg = get_smoke_config("llama3p2_1b")
    # 4 super-blocks so a 2-stage pipeline holds 2 each
    cfg = dataclasses.replace(cfg, num_layers=4)
    mesh = mesh_lib.make_debug_pp_mesh(2, 2)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    pcfg = PP.PipelineConfig(num_stages=2, num_microbatches=4)
"""


@pytest.mark.slow
def test_pp_loss_matches_single_pass():
    run_sub(COMMON + """
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        loss_fn = PP.make_pipeline_loss(cfg, pcfg, mesh)
        l_pp, m_pp = jax.jit(loss_fn)(params, batch)
    l_ref, m_ref = Mod.loss_fn(params, cfg, batch, remat=False)
    np.testing.assert_allclose(float(l_pp), float(l_ref), rtol=2e-3)
    np.testing.assert_allclose(float(m_pp["loss"]), float(m_ref["loss"]),
                               rtol=2e-3)
    print("ok", float(l_pp), float(l_ref))
    """)


@pytest.mark.slow
def test_pp_grads_match_single_pass():
    """The autodiff-transposed reverse pipeline == plain backward, for every
    stage's blocks AND the pipe-replicated embed/head."""
    run_sub(COMMON + """
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        loss_fn = PP.make_pipeline_loss(cfg, pcfg, mesh)
        g_pp = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(params, batch)
    g_ref = jax.grad(lambda p: Mod.loss_fn(p, cfg, batch, remat=False)[0])(
        params)
    flat_pp = jax.tree_util.tree_leaves_with_path(g_pp)
    flat_ref = {jax.tree_util.keystr(k): v
                for k, v in jax.tree_util.tree_leaves_with_path(g_ref)}
    checked = 0
    for k, v in flat_pp:
        ref = flat_ref[jax.tree_util.keystr(k)]
        np.testing.assert_allclose(
            np.asarray(v, np.float32), np.asarray(ref, np.float32),
            atol=2e-2, rtol=2e-2, err_msg=jax.tree_util.keystr(k))
        checked += 1
    assert checked >= 10
    print("ok", checked, "leaves")
    """)


@pytest.mark.slow
def test_pp_train_step_runs_and_updates():
    run_sub(COMMON + """
    from repro.optim import adamw
    opt_cfg = adamw.AdamWConfig(warmup_steps=1)
    opt = adamw.init_opt_state(params)
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        step = jax.jit(PP.make_pp_train_step(cfg, opt_cfg, pcfg, mesh))
        p1, o1, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p1)
    assert max(jax.tree.leaves(d)) > 0
    print("ok", float(metrics["loss"]))
    """)


def test_bubble_fraction():
    from repro.distributed import pipeline as PP
    assert PP.bubble_fraction(PP.PipelineConfig(4, 4)) == pytest.approx(3 / 7)
    assert PP.bubble_fraction(PP.PipelineConfig(4, 32)) == pytest.approx(
        3 / 35)
    with pytest.raises(AssertionError):
        PP.PipelineConfig(num_stages=4, num_microbatches=2)
