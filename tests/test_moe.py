"""MoE: sort-based dispatch vs compute-all-experts oracle + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import moe as M
from repro.core.types import MoESpec


def make(rng, t=64, d=32, f=64, e=8, k=2):
    spec = MoESpec(num_experts=e, top_k=k)
    params = M.init_moe(jax.random.PRNGKey(0), d, f, spec, dtype=jnp.float32)
    x = jnp.asarray(rng.randn(1, t, d) * 0.5, jnp.float32)
    return spec, params, x


def test_dispatch_matches_dense_ref(rng):
    spec, params, x = make(rng)
    # capacity_factor big enough that nothing drops
    got, aux = M.moe_ffn(params, x, spec, capacity_factor=8.0)
    want = M.moe_ffn_dense_ref(params, x, spec)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50),
       e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2, 4]))
def test_dispatch_matches_ref_property(seed, e, k):
    rng = np.random.RandomState(seed)
    spec, params, x = make(rng, t=32, e=e, k=min(k, e))
    got, _ = M.moe_ffn(params, x, spec, capacity_factor=float(e))
    want = M.moe_ffn_dense_ref(params, x, spec)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


def test_capacity_drops_bound_output(rng):
    """With tight capacity some tokens drop — output must stay finite and
    dropped tokens contribute zero (not garbage)."""
    spec, params, x = make(rng, t=128, e=4, k=2)
    got, _ = M.moe_ffn(params, x, spec, capacity_factor=0.25)
    assert bool(jnp.isfinite(got).all())
    ref_out = M.moe_ffn_dense_ref(params, x, spec)
    # dropped-token output norm <= reference norm (combine only removes mass)
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(ref_out)) * 1.5


def test_gates_renormalized(rng):
    """Top-k gate weights sum to 1 per token (renormalized softmax)."""
    spec, params, x = make(rng)
    logits = x.reshape(-1, x.shape[-1]).astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, _ = jax.lax.top_k(probs, spec.top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    np.testing.assert_allclose(np.asarray(gv.sum(-1)), 1.0, atol=1e-6)


def test_aux_loss_uniform_router_is_one(rng):
    """Switch aux loss equals 1.0 for a perfectly uniform router."""
    spec, params, x = make(rng, e=4, k=1)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform routing
    _, aux = M.moe_ffn(params, x, spec, capacity_factor=4.0)
    assert abs(float(aux) - 1.0) < 0.05


def test_moe_grads_flow(rng):
    spec, params, x = make(rng)
    def loss(p):
        y, aux = M.moe_ffn(p, x, spec, capacity_factor=4.0)
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.grad(loss)(params)
    for name in ("router", "w1", "w2", "w3"):
        assert float(jnp.abs(g[name]).max()) > 0, name
