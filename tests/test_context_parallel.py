"""Context-parallel (halo-exchange) window attention vs the O(N^2) oracle.

Subprocess pattern (device count must be set before jax init): a 4-device
1D mesh shards the sequence; the CP output must match attention_ref bit-for
tolerance, including sequence edges, global rows/cols, GQA, multi-hop halos
(w > Lp) and gradients through the ppermutes.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

COMMON = """
    import dataclasses, functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.types import AttentionSpec
    from repro.distributed import context_parallel as CP
    from repro.kernels import ref as R

    assert len(jax.devices()) == 4
    mesh = jax.make_mesh((4,), ("seq",))

    def run_case(spec, b=2, hq=4, hkv=2, l=64, d=16, tol=2e-2):
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(b, hq, l, d), jnp.float32) * 0.5
        k = jnp.asarray(rng.randn(b, hkv, l, d), jnp.float32) * 0.5
        v = jnp.asarray(rng.randn(b, hkv, l, d), jnp.float32) * 0.5
        with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
            got = CP.swat_attention_context_parallel(
                q, k, v, spec, mesh=mesh, axis="seq",
                block_q=16, block_kv=16)
        want = R.attention_ref(q, k, v, spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=tol, rtol=tol)
        return q, k, v
"""


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c",
                          textwrap.dedent(COMMON + code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_cp_causal_window():
    run_sub("""
        run_case(AttentionSpec(kind="swat", window=8, causal=True))
        print("ok")
    """)


@pytest.mark.slow
def test_cp_causal_window_global():
    run_sub("""
        run_case(AttentionSpec(kind="swat", window=8, num_global=4,
                               causal=True))
        print("ok")
    """)


@pytest.mark.slow
def test_cp_bidirectional_global():
    """Longformer-encoder style (the paper's own LRA configuration)."""
    run_sub("""
        run_case(AttentionSpec(kind="swat", window=8, num_global=4,
                               causal=False))
        print("ok")
    """)


@pytest.mark.slow
def test_cp_multihop_halo():
    """w > Lp: the halo spans two neighbour shards (2 ppermute hops)."""
    run_sub("""
        spec = AttentionSpec(kind="swat", window=24, causal=True)
        assert CP.halo_hops(24, 16) == 2
        run_case(spec)
        spec = AttentionSpec(kind="swat", window=24, causal=False)
        run_case(spec)
        print("ok")
    """)


@pytest.mark.slow
def test_cp_softcap_and_gqa():
    run_sub("""
        run_case(AttentionSpec(kind="swat", window=8, causal=True,
                               softcap=30.0), hq=8, hkv=2)
        print("ok")
    """)


@pytest.mark.slow
def test_cp_gradients():
    """shard_map transposes the halo ppermutes: grads match the oracle."""
    run_sub("""
        spec = AttentionSpec(kind="swat", window=8, num_global=4, causal=True)
        rng = np.random.RandomState(1)
        b, hq, hkv, l, d = 1, 2, 2, 64, 8
        q = jnp.asarray(rng.randn(b, hq, l, d), jnp.float32) * 0.5
        k = jnp.asarray(rng.randn(b, hkv, l, d), jnp.float32) * 0.5
        v = jnp.asarray(rng.randn(b, hkv, l, d), jnp.float32) * 0.5
        t = jnp.asarray(rng.randn(b, hq, l, d), jnp.float32)

        def loss_cp(q, k, v):
            with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
                o = CP.swat_attention_context_parallel(
                    q, k, v, spec, mesh=mesh, axis="seq",
                    block_q=16, block_kv=16)
            return jnp.sum((o.astype(jnp.float32) - t) ** 2)

        def loss_ref(q, k, v):
            o = R.attention_ref(q, k, v, spec)
            return jnp.sum((o.astype(jnp.float32) - t) ** 2)

        g_cp = jax.grad(loss_cp, argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g_cp, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=5e-2, rtol=5e-2)
        print("ok")
    """)


@pytest.mark.slow
def test_cp_wire_bytes_vs_allgather():
    """The headline claim: halo wire bytes are O(w), independent of L."""
    from repro.distributed import context_parallel as CP
    w, h, d = 512, 16, 64
    for L in (16384, 65536, 524288):
        halo = CP.cp_wire_bytes_per_device(L, 16, w, h, d)
        # all-gather alternative moves the full remote KV: (L - Lp) * 2 * row
        allgather = 2 * (L - L // 16) * h * d * 2
        assert halo < allgather / 10, (L, halo, allgather)
    # halo bytes CONSTANT in L once the window fits one shard: O(w) exactly
    assert (CP.cp_wire_bytes_per_device(2 ** 14, 16, w, h, d)
            == CP.cp_wire_bytes_per_device(2 ** 19, 16, w, h, d)
            == 2 * w * h * d * 2)
    # multi-hop (w > Lp) ships whole shards, bounded by 2w
    assert CP.cp_wire_bytes_per_device(2 ** 10, 16, w, h, d) \
        <= 2 * 2 * w * h * d * 2
