"""Block-pattern properties: the static sparsity schedule is the paper's
'synthesis-time parameter' analogue — these invariants are what make the
kernels correct by construction."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import patterns
from repro.core.types import AttentionSpec


def spec_strategy():
    return st.builds(
        AttentionSpec,
        kind=st.just("swat"),
        window=st.sampled_from([16, 33, 64, 100]),
        num_global=st.sampled_from([0, 7, 32]),
        num_random=st.sampled_from([0, 1, 2]),
        random_seed=st.integers(0, 5),
        causal=st.booleans(),
    )


@settings(max_examples=30, deadline=None)
@given(spec=spec_strategy(),
       seq=st.sampled_from([128, 200, 512]),
       blk=st.sampled_from([32, 64, 128]))
def test_pattern_covers_mask(spec, seq, blk):
    """Every (i, j) allowed by the token-level mask must lie in some visited
    block — else the kernel would silently drop attention edges."""
    pat = patterns.build_block_pattern(spec, seq, seq, blk, blk)
    mask = patterns.random_blocks_mask(pat)
    covered = np.zeros((pat.num_q_blocks, pat.num_kv_blocks), bool)
    for i in range(pat.num_q_blocks):
        for s in range(pat.num_slots):
            if pat.slot_kinds[i, s] != patterns.PAD:
                covered[i, pat.kv_block_map[i, s]] = True
    need = np.zeros_like(covered)
    # rows i < num_global are the dense global-rows pass's responsibility
    # (ops.swat_attention replaces them wholesale), not the band pattern's
    for i in range(spec.num_global, seq):
        for j in np.where(mask[i])[0]:
            need[i // blk, j // blk] = True
    assert not (need & ~covered).any()


@settings(max_examples=30, deadline=None)
@given(spec=spec_strategy(), seq=st.sampled_from([128, 512]),
       blk=st.sampled_from([64, 128]))
def test_no_duplicate_slots(spec, seq, blk):
    """A kv block must appear at most once per q block (double counting
    would double softmax mass)."""
    pat = patterns.build_block_pattern(spec, seq, seq, blk, blk)
    for i in range(pat.num_q_blocks):
        live = [pat.kv_block_map[i, s] for s in range(pat.num_slots)
                if pat.slot_kinds[i, s] != patterns.PAD]
        assert len(live) == len(set(live)), (i, live)


@settings(max_examples=20, deadline=None)
@given(spec=spec_strategy(), seq=st.sampled_from([256, 512]))
def test_inverse_pattern_is_transpose(spec, seq):
    pat = patterns.build_block_pattern(spec, seq, seq, 64, 64)
    inv = pat.inverse()
    fwd_edges = set()
    for i in range(pat.num_q_blocks):
        for s in range(pat.num_slots):
            if pat.slot_kinds[i, s] != patterns.PAD:
                fwd_edges.add((i, int(pat.kv_block_map[i, s])))
    inv_edges = set()
    for j in range(inv.q_block_map.shape[0]):
        for s in range(inv.num_slots):
            if inv.slot_kinds[j, s] != patterns.PAD:
                inv_edges.add((int(inv.q_block_map[j, s]), j))
    assert fwd_edges == inv_edges


def test_active_fraction_linear_scaling():
    """Paper Fig. 3: window attention block count grows linearly with
    sequence length => active fraction ~ 1/N."""
    spec = AttentionSpec(kind="swat", window=128, causal=False)
    fracs = []
    for seq in (1024, 2048, 4096):
        pat = patterns.build_block_pattern(spec, seq, seq, 128, 128)
        fracs.append(pat.active_fraction() * seq)
    # N * active_fraction ~ constant band width in blocks
    assert max(fracs) / min(fracs) < 1.4, fracs


def test_sliding_chunks_redundancy_formula():
    """Paper §1: redundancy -> 1/2 as chunks grow."""
    r1 = patterns.sliding_chunks_flops_ratio(1024, 64)
    r2 = patterns.sliding_chunks_flops_ratio(65536, 64)
    assert r1 < r2 < 0.5
    assert abs(r2 - 0.5) < 1e-3


def test_causal_mask_has_no_future_leak():
    spec = AttentionSpec(kind="swat", window=32, num_global=8, num_random=1,
                         causal=True, random_seed=1)
    pat = patterns.build_block_pattern(spec, 256, 256, 64, 64)
    mask = patterns.random_blocks_mask(pat)
    i, j = np.triu_indices(256, k=1)
    assert not mask[i, j].any()
