"""Sharding-rule unit tests: profiles produce the intended PartitionSpecs.

These are the §Perf levers — wrong specs silently degrade to replication,
so pin them. Subprocess for a real 4-device mesh."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.core import model as Mod
    from repro.distributed import sharding as Sh
    from repro.launch import mesh as mesh_lib

    cfg = get_smoke_config("llama3p2_1b")
    mesh = mesh_lib.make_debug_mesh(2, 2)
    specs = jax.eval_shape(lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))

    def spec_of(tree, *path):
        node = tree
        for p in path:
            node = node[p]
        return tuple(node.spec)
"""


@pytest.mark.slow
def test_tp_profile_megatron_pairs():
    """Column-parallel in-proj over 'model', row-parallel out-proj, FSDP on
    the complementary dim."""
    run_sub(COMMON + """
    sh = Sh.param_sharding(specs, mesh, profile="tp")
    # blocks leaves have the stacked super-block dim 0 -> rules shift by 1
    wq = spec_of(sh, "blocks", "l0", "mixer", "wq")
    wo = spec_of(sh, "blocks", "l0", "mixer", "wo")
    assert wq[2] == "model" and wq[1] == "data", wq   # col-parallel + FSDP
    assert wo[1] == "model" and wo[2] == "data", wo   # row-parallel + FSDP
    print("ok", wq, wo)
    """)


@pytest.mark.slow
def test_cp_profile_no_tensor_parallel_dims():
    """cp/fsdp profiles: 2D-FSDP only — no matmul-partitioned dims."""
    run_sub(COMMON + """
    sh = Sh.param_sharding(specs, mesh, profile="cp")
    wq = spec_of(sh, "blocks", "l0", "mixer", "wq")
    # dim 1 (d_model=64, divisible by 4) takes the combined FSDP axes
    assert wq[1] == ("data", "model"), wq
    assert wq[2] is None, wq
    print("ok", wq)
    """)


@pytest.mark.slow
def test_fsdp_profile_batch_over_all_axes():
    run_sub(COMMON + """
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    b_tp = Sh.batch_sharding(batch, mesh, profile="tp")["tokens"].spec
    b_fs = Sh.batch_sharding(batch, mesh, profile="fsdp")["tokens"].spec
    def axes(entry):   # PartitionSpec normalizes 1-tuples to bare strings
        if entry is None:
            return ()
        return (entry,) if isinstance(entry, str) else tuple(entry)

    assert axes(tuple(b_tp)[0]) == ("data",), b_tp
    assert axes(tuple(b_fs)[0]) == ("data", "model"), b_fs
    a_tp = Sh.activation_spec(mesh, True, "tp")
    a_fs = Sh.activation_spec(mesh, True, "fsdp")
    assert axes(tuple(a_tp)[0]) == ("data",) and tuple(a_tp)[1] == "model"
    assert axes(tuple(a_fs)[0]) == ("data", "model")
    assert tuple(a_fs)[1] is None
    print("ok")
    """)


@pytest.mark.slow
def test_pipe_mesh_shards_superblock_dim():
    run_sub(COMMON + """
    import dataclasses
    pmesh = mesh_lib.make_debug_pp_mesh(2, 2)
    cfg4 = dataclasses.replace(cfg, num_layers=4)   # 4 super-blocks
    sp4 = jax.eval_shape(lambda: Mod.init_model(jax.random.PRNGKey(0), cfg4))
    sh = Sh.param_sharding(sp4, pmesh, profile="tp")
    wq = spec_of(sh, "blocks", "l0", "mixer", "wq")
    assert wq[0] == "pipe", wq
    emb = spec_of(sh, "embed")
    assert "pipe" not in emb, emb      # non-block leaves stay unstaged
    print("ok", wq)
    """)


@pytest.mark.slow
def test_divisibility_fallback_replicates():
    """Indivisible dims must fall through to the next preference, never
    produce an invalid spec."""
    run_sub(COMMON + """
    import dataclasses, jax.numpy as jnp
    # vocab 50280 % 2 == 0 but % 4 != 0: embed dim0 tries (model,data)
    cfg2 = dataclasses.replace(cfg, vocab_size=50281)   # prime-ish: no axis
    sp = jax.eval_shape(lambda: Mod.init_model(jax.random.PRNGKey(0), cfg2))
    sh = Sh.param_sharding(sp, mesh, profile="tp")
    emb = tuple(sh["embed"].spec)
    assert emb[0] is None, emb         # indivisible -> replicated dim
    assert emb[1] == "data", emb       # second rule still lands
    print("ok", emb)
    """)
