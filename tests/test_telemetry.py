"""swatscope telemetry: device counters, lifecycle tracing, exports.

The observability contract (src/repro/telemetry/ + the engine hooks):

  * metrics-on decode is BITWISE identical to metrics-off — both decode
    impls, sequential and speculative, and under chaos (the counter
    pytree is one extra donated int32 carry; it never touches sampling,
    RNG, or control flow),
  * device counters match a hand-computed oracle: tokens/pos equal the
    in-scan emission count, ring_wraps equals the revolutions of the
    narrowest logical ring, speculative draft counters equal the host's
    per-attempt accounting, the chaos drill bumps `quarantined` exactly
    once,
  * per-attempt vs per-request accounting: `tokens_emitted` counts work
    (retries re-count), `tokens_delivered` counts exactly the tokens
    clients received — a kernel-fallback retry never double-counts,
  * the tracer holds O(capacity) memory under sustained load and its
    exports (Chrome trace JSON, Prometheus text) pass the telemetry
    schema validators,
  * degradation events flow over ONE bus: `faults.record_event` is a
    shim over `telemetry.events`, and engine tracers see the same stream
    `consume_events()` drains.

The 4-device slot-parallel metrics-identity case lives in
test_serving_sharded.py with the other mesh suites.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config, with_swat
from repro.core import model as Mod
from repro.serving import faults as F
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan
from repro.telemetry import events as TEV
from repro.telemetry import kernelprof as KP
from repro.telemetry import metrics as MX
from repro.telemetry.tracer import Tracer, prometheus_text
from repro.telemetry.validate import (validate_chrome_trace,
                                      validate_prometheus)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def swat_setup():
    cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(autouse=True)
def _clean_event_bus():
    F.consume_events()
    yield
    F.consume_events()


def mkreqs(cfg, n=3, m=10, plen=12):
    return [Request(rid=i, prompt=np.random.RandomState(i).randint(
                0, cfg.vocab_size, (plen,)).astype(np.int32),
                max_new_tokens=m) for i in range(n)]


def by_rid(results):
    return {r.rid: r for r in results}


# ------------------------------------------------- bitwise identity ----


def _identity_case(cfg, params, *, n=3, m=10, **kw):
    off = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, metrics=False, **kw)
    ref = by_rid(off.run(mkreqs(cfg, n=n, m=m)))
    on = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                       scan_steps=4, metrics=True, **kw)
    out = by_rid(on.run(mkreqs(cfg, n=n, m=m)))
    for i in ref:
        assert out[i].status == ref[i].status
        assert out[i].tokens == ref[i].tokens, (i, out[i].tokens,
                                                ref[i].tokens)
    return on


def test_metrics_identity_ref(setup):
    eng = _identity_case(*setup)
    dev = eng.device_metrics()
    assert dev["tokens"] == eng.stats["tokens_emitted"]


def test_metrics_identity_pallas(swat_setup):
    eng = _identity_case(*swat_setup, decode_impl="pallas")
    assert eng.device_metrics()["tokens"] == eng.stats["tokens_emitted"]


def test_metrics_identity_speculative(setup):
    eng = _identity_case(*setup, speculative=2)
    dev = eng.device_metrics()
    assert dev["drafts_proposed"] == eng.stats["draft_proposed"]
    assert dev["drafts_accepted"] == eng.stats["draft_accepted"]


def test_metrics_identity_under_chaos(setup):
    """The quarantine path with counters compiled in: healthy slots
    bitwise identical, the poisoned request degrades identically."""
    cfg, params = setup
    plan = FaultPlan(poison_logits=((0, 3, "nan"),))
    off = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, faults=plan)
    ref = by_rid(off.run(mkreqs(cfg)))
    on = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                       scan_steps=4, faults=plan, metrics=True)
    out = by_rid(on.run(mkreqs(cfg)))
    for i in ref:
        assert (out[i].status, out[i].tokens) == (ref[i].status,
                                                  ref[i].tokens)
    dev = on.device_metrics()
    assert dev["quarantined"] == 1 == on.stats["quarantined"]


# ------------------------------------------------- counter oracles ----


def test_counter_oracle_sequential(setup):
    """Sequential decode, no faults: the device counters must equal the
    hand count — every request emits max_new_tokens - 1 tokens in-scan
    (the first token is sampled at prefill, host-side), pos mirrors
    tokens, nothing quarantines, and per-step emission bounds steps."""
    cfg, params = setup
    n, m = 3, 10
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, metrics=True)
    out = by_rid(eng.run(mkreqs(cfg, n=n, m=m)))
    assert all(r.status == "ok" and len(r.tokens) == m
               for r in out.values())
    dev = eng.device_metrics()
    want = n * (m - 1)
    assert dev["tokens"] == want == eng.stats["tokens_emitted"]
    assert dev["pos"] == want          # sequential: one write per emit
    assert dev["quarantined"] == 0
    assert dev["drafts_proposed"] == 0 == dev["drafts_accepted"]
    assert dev["steps"] > 0
    # each scan iteration emits at most one token per slot
    assert want <= dev["steps"] * eng.slots
    assert eng.stats["tokens_delivered"] == n * m


def test_counter_oracle_ring_wraps(swat_setup):
    """One slot, one long request: decode writes wrap the narrowest
    logical ring exactly floor(emitted / modulus) times."""
    cfg, params = swat_setup
    m = 40
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64,
                        scan_steps=4, metrics=True)
    out = eng.run(mkreqs(cfg, n=1, m=m))
    assert out[0].status == "ok" and len(out[0].tokens) == m
    mod = MX.ring_modulus(cfg, 64)
    assert eng._c.ring_mod == mod
    dev = eng.device_metrics()
    assert dev["tokens"] == m - 1
    assert dev["ring_wraps"] == (m - 1) // mod, (dev, mod)


def test_counter_oracle_speculative(setup):
    """Speculative verify: device draft counters mirror the host's
    accounting identity — every verify step that ran proposed k drafts
    and kept emitted - 1 of them."""
    cfg, params = setup
    k = 2
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, speculative=k, metrics=True)
    out = by_rid(eng.run(mkreqs(cfg, n=3, m=10)))
    assert all(r.status == "ok" for r in out.values())
    dev = eng.device_metrics()
    assert dev["drafts_proposed"] == eng.stats["draft_proposed"] > 0
    assert dev["drafts_accepted"] == eng.stats["draft_accepted"]
    assert dev["tokens"] == eng.stats["tokens_emitted"]
    # identity: emitted = verify steps that ran + accepted drafts
    assert (dev["tokens"]
            == dev["drafts_proposed"] // k + dev["drafts_accepted"])


def test_counters_survive_admission_waves(setup):
    """More requests than slots: counters are engine-lifetime totals,
    accumulated across slot reuse — never reset by a restage."""
    cfg, params = setup
    n, m = 5, 8                  # 5 requests through 2 slots
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, metrics=True)
    out = by_rid(eng.run(mkreqs(cfg, n=n, m=m)))
    assert all(r.status == "ok" for r in out.values())
    assert eng.device_metrics()["tokens"] == n * (m - 1)


# ------------------------------------- per-attempt vs per-request ----


def test_retry_never_double_counts_delivered(swat_setup):
    """A pallas dispatch failure retries the block after recompiling with
    the ref impl. `tokens_emitted` counts per-attempt WORK; the new
    `tokens_delivered` must equal exactly the tokens clients received."""
    cfg, params = swat_setup
    F.consume_events()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                                scan_steps=2, decode_impl="pallas",
                                metrics=True,
                                faults=FaultPlan(fail_pallas_dispatch=True))
            out = eng.run(mkreqs(cfg, m=8))
    finally:
        F.clear_kernel_failure()
    assert eng.stats["kernel_fallbacks"] == 1
    assert all(r.status == "ok" for r in out)
    assert eng.stats["tokens_delivered"] == sum(len(r.tokens) for r in out)
    # a retried attempt's record counts once per admission in the tracer
    recs = {r.rid: r for r in eng.tracer.records}
    assert all(recs[r.rid].tokens == len(r.tokens) for r in out)


# ----------------------------------------------------- the tracer ----


def test_tracer_ring_bounds_memory():
    """Sustained load holds O(capacity) records — the deque drops the
    oldest, latency summaries keep working, nothing grows unbounded."""
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    tr = Tracer(capacity=8, clock=clock)
    for rid in range(100):
        tr.on_submit(rid)
        tr.on_admit([rid])
        tr.on_first_token([rid])
        tr.on_block("seq", 4, clock(), 4)
        tr.on_finish(rid, "ok", 5)
    assert len(tr.records) == 8
    assert len(tr.blocks) == 8
    assert {r.rid for r in tr.records} == set(range(92, 100))
    summ = tr.latency_summary()
    assert summ["ttft"]["count"] == 8
    assert summ["tpot"]["p50"] > 0
    assert tr.dropped_requests == 0


def test_tracer_deterministic_latency_math():
    """With an injected clock the derived latencies are exact: ttft =
    first_token - submit, tpot = (finish - first) / (tokens - 1),
    queue_delay = admit - submit."""
    t = {"now": 0.0}

    def clock():
        return t["now"]

    tr = Tracer(capacity=4, clock=clock)
    tr.on_submit(7)
    t["now"] = 1.0
    tr.on_admit([7])
    t["now"] = 3.0
    tr.on_first_token([7])
    t["now"] = 11.0
    tr.on_finish(7, "ok", 5)
    (rec,) = tr.records
    assert rec.queue_delay == 1.0
    assert rec.ttft == 3.0
    assert rec.tpot == (11.0 - 3.0) / 4


def test_tracer_retry_restarts_attempt_clock():
    """A second admission of the same rid is a retry: attempts bumps and
    the first-token clock resets, but submit (the client's clock) holds."""
    t = {"now": 0.0}
    tr = Tracer(capacity=4, clock=lambda: t["now"])
    tr.on_submit(0)
    t["now"] = 1.0
    tr.on_admit([0])
    t["now"] = 2.0
    tr.on_first_token([0])
    t["now"] = 3.0
    tr.on_admit([0])              # retry
    t["now"] = 5.0
    tr.on_first_token([0])
    t["now"] = 9.0
    tr.on_finish(0, "ok", 3)
    (rec,) = tr.records
    assert rec.attempts == 2
    assert rec.submit == 0.0 and rec.admit == 3.0
    assert rec.ttft == 5.0


# ------------------------------------------------- exports + schema ----


def test_exports_validate(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, metrics=True)
    eng.run(mkreqs(cfg))
    doc = eng.chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert any(ev.get("cat") == "request" for ev in doc["traceEvents"])
    text = eng.metrics_text()
    assert validate_prometheus(text) == []
    assert "swat_device_tokens" in text
    assert 'quantile="0.95"' in text


def test_validators_reject_garbage():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]}) != []
    assert validate_prometheus("") != []
    assert validate_prometheus("no type line 1\n") != []
    good = prometheus_text({"a_total": 3})
    assert validate_prometheus(good) == []


def test_snapshot_round_trips(setup):
    import json

    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, metrics=True)
    eng.run(mkreqs(cfg, n=2, m=6))
    snap = eng.snapshot()
    assert snap["device"]["tokens"] == eng.stats["tokens_emitted"]
    assert snap["stats"]["tokens_delivered"] == 12
    json.dumps(snap)              # everything JSON-serializable


# -------------------------------------------------- unified events ----


def test_event_bus_single_stream(setup):
    """faults.record_event IS telemetry.events.record_event, subscribed
    engine tracers see the same event, and consume drains one queue."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    F.record_event("synthetic_drill", rid=42)
    assert TEV.peek_events()[-1]["kind"] == "synthetic_drill"
    assert eng.tracer.events[-1]["kind"] == "synthetic_drill"
    drained = F.consume_events()
    assert [e["kind"] for e in drained] == ["synthetic_drill"]
    assert TEV.consume_events() == []          # one queue, now empty
    # the tracer keeps its bounded copy for the chrome trace
    assert eng.tracer.events[-1]["rid"] == 42


def test_quarantine_event_reaches_tracer(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4,
                        faults=FaultPlan(poison_logits=((0, 3, "nan"),)))
    eng.run(mkreqs(cfg))
    kinds = [e["kind"] for e in eng.tracer.events]
    assert "slot_quarantined" in kinds
    names = [ev["name"] for ev in eng.chrome_trace()["traceEvents"]]
    assert "slot_quarantined" in names


# ------------------------------------------------ kernel profiling ----


def test_dispatch_census_is_trace_time(setup):
    """The census records one entry per compiled shape regardless of how
    many steps execute, and is inert when disabled."""
    cfg, params = setup
    KP.consume_census()
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4)
    eng.run(mkreqs(cfg, n=2, m=6))
    assert KP.consume_census() == []           # disabled: zero overhead
    KP.enable_census(True)
    try:
        # distinct scan_steps -> a fresh compile (the engine memoizes
        # compiled programs per shape; the census records at trace time)
        eng2 = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                             scan_steps=3)
        eng2.run(mkreqs(cfg, n=2, m=6))
    finally:
        KP.enable_census(False)
    census = KP.consume_census()
    assert census, "census saw no decode dispatches"
    ops = {rec["op"] for rec in census}
    assert "decode_attention" in ops
    assert all(rec["traces"] >= 1 for rec in census)


def test_banded_cost_is_window_linear():
    """The paper's O(window) claim in the analytic model: doubling the
    window ~doubles banded FLOPs while dense FLOPs track cap."""
    base = dict(b=1, h_q=4, h_kv=2, t=1, d=64, cap=4096, num_global=4)
    w64 = KP.banded_decode_cost(window=64, **base)
    w128 = KP.banded_decode_cost(window=128, **base)
    dense = KP.banded_decode_cost(window=0, **base)
    assert 1.5 < w128["flops"] / w64["flops"] < 2.1
    assert dense["flops"] > 20 * w64["flops"]
    assert w64["band_rows"] == 64 + 4 + 1
