"""Generative sweep of the ring-cache invariants.

Ring masking has now bitten twice at hand-picked shapes (PR 2's
tile-rounding tail, PR 3's silently-dropped `spec.window`), and the
speculative rollback leans on a third property (stale slots reconstruct to
window-masked positions). So pin the whole contract down generatively
across randomized (window, num_global, lookahead, wrap-point, raggedness):

  * `ring_slot_positions` == a literal numpy FIFO simulation (insert the
    tokens one by one, remember who lives where),
  * `ring_insert_ref` == the same simulation for ragged multi-row inserts,
  * the fused pallas kernel's IN-KERNEL insert produces bitwise the same
    cache as `ring_insert_ref`, and its attention output matches the
    unfused ref oracle, across wrap points and ragged `num_new`,
  * rollback safety: after insert-then-rollback, every garbage slot
    reconstructs to a position outside every live query's window (the
    no-resurrection guarantee speculative decode relies on).

Runs under the real `hypothesis` when installed, else the deterministic
tests/hypothesis_fallback.py shim (the CI spec-decode lane's mode).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.layers import _round_capacity
from repro.core.types import AttentionSpec
from repro.kernels.ops import decode_attention
from repro.kernels.ref import ring_insert_ref, ring_slot_positions


@dataclasses.dataclass(frozen=True)
class RingGeom:
    """One randomized ring geometry: logical capacity window+1+lookahead+g
    (the serving cache law), physical width tile-rounded above it."""
    window: int
    num_global: int
    lookahead: int

    @property
    def cap(self) -> int:           # logical rotation modulus
        return self.window + 1 + self.lookahead + self.num_global

    @property
    def wcap(self) -> int:          # physical rows incl. rounding tail
        return _round_capacity(self.cap)


GEOMS = st.builds(RingGeom,
                  window=st.integers(1, 24),
                  num_global=st.sampled_from([0, 1, 3, 4]),
                  lookahead=st.integers(0, 5))


def fifo_sim(geom: RingGeom, total: int):
    """Token-by-token numpy simulation: who lives in which slot after
    inserting tokens 0..total-1. Returns (pos (W,), valid (W,))."""
    g, ring = geom.num_global, geom.cap - geom.num_global
    pos = np.full((geom.wcap,), -1, np.int64)
    for p in range(total):
        slot = p if p < g else g + (p - g) % ring
        pos[slot] = p
    return pos, pos >= 0


@settings(max_examples=40)
@given(geom=GEOMS, seed=st.integers(0, 10_000))
def test_slot_positions_match_fifo_simulation(geom, seed):
    rng = np.random.RandomState(seed)
    # wrap-points: empty, partial, exactly full, wrapped, multi-wrapped
    totals = np.array([0, 1,
                       rng.randint(0, geom.cap + 1),
                       geom.cap,
                       geom.cap + rng.randint(1, geom.cap + 1),
                       rng.randint(2, 5) * geom.cap + rng.randint(0, geom.cap)
                       ], np.int32)
    t_s, valid = ring_slot_positions(jnp.asarray(totals), geom.wcap,
                                     ring_cap=geom.cap,
                                     num_global=geom.num_global)
    t_s, valid = np.asarray(t_s), np.asarray(valid)
    for b, total in enumerate(totals):
        want_pos, want_valid = fifo_sim(geom, int(total))
        assert (valid[b] == want_valid).all(), (geom, total)
        assert (t_s[b][want_valid] == want_pos[want_valid]).all(), \
            (geom, total)
        # the tile-rounding tail is NEVER valid (the PR-2 bug)
        assert not valid[b][geom.cap:].any(), (geom, total)


@settings(max_examples=40)
@given(geom=GEOMS, t=st.integers(1, 6), seed=st.integers(0, 10_000))
def test_ring_insert_matches_fifo_simulation(geom, t, seed):
    """Ragged multi-row insert == insert the rows one by one in numpy,
    skipping rows past each slot's num_new."""
    t = min(t, geom.lookahead + 1)      # the engine's own allocation law
    rng = np.random.RandomState(seed)
    b, h, d = 3, 2, 4
    cache = rng.randn(b, h, geom.wcap, d).astype(np.float32)
    new = rng.randn(b, h, t, d).astype(np.float32)
    pos = np.array([rng.randint(0, 3 * geom.cap) for _ in range(b)], np.int32)
    num_new = np.array([rng.randint(0, t + 1) for _ in range(b)], np.int32)

    got = np.asarray(ring_insert_ref(
        jnp.asarray(cache), jnp.asarray(new), jnp.asarray(pos),
        jnp.asarray(num_new), ring_cap=geom.cap,
        num_global=geom.num_global))

    want = cache.copy()
    g, ring = geom.num_global, geom.cap - geom.num_global
    for bi in range(b):
        for j in range(int(num_new[bi])):
            p = int(pos[bi]) + j
            slot = p if p < g else g + (p - g) % ring
            want[bi, :, slot] = new[bi, :, j]
    assert (got == want).all(), (geom, t, pos.tolist(), num_new.tolist())


@settings(max_examples=25)
@given(geom=GEOMS, t=st.integers(1, 4), seed=st.integers(0, 10_000),
       causal=st.just(True))
def test_fused_kernel_insert_matches_ref(geom, t, seed, causal):
    """decode_attention(impl='pallas', new_kv=...) — the in-kernel
    input/output-aliased insert — returns bitwise the ring_insert_ref
    cache and a matching attention output, across randomized geometry,
    wrap point, and ragged num_new. This is the oracle pair the serving
    engine's two decode impls ride."""
    t = min(t, geom.lookahead + 1)
    spec = AttentionSpec(kind="swat", causal=causal, window=geom.window,
                         num_global=geom.num_global)
    rng = np.random.RandomState(seed)
    b, hq, hkv, d = 2, 4, 2, 8          # GQA group 2
    # per-slot wrap points; every query position must exist (pos >= t is
    # not required — pos counts BEFORE the insert, queries are the new
    # tokens — but positions must cover the pinned prefix)
    pos = np.array([rng.randint(geom.num_global, 3 * geom.cap),
                    rng.randint(geom.num_global, 3 * geom.cap)], np.int32)
    num_new = np.array([t, rng.randint(1, t + 1)], np.int32)
    q = rng.randn(b, hq, t, d).astype(np.float32)
    kc = rng.randn(b, hkv, geom.wcap, d).astype(np.float32)
    vc = rng.randn(b, hkv, geom.wcap, d).astype(np.float32)
    kn = rng.randn(b, hkv, t, d).astype(np.float32)
    vn = rng.randn(b, hkv, t, d).astype(np.float32)

    args = (jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), None, spec)
    kw = dict(new_kv=(jnp.asarray(kn), jnp.asarray(vn)),
              num_new=jnp.asarray(num_new), pos=jnp.asarray(pos),
              ring_cap=geom.cap)
    out_r, kc_r, vc_r = decode_attention(*args, impl="ref", **kw)
    out_p, kc_p, vc_p = decode_attention(*args, impl="pallas",
                                         interpret=True, **kw)
    assert (np.asarray(kc_r) == np.asarray(kc_p)).all(), (geom, t)
    assert (np.asarray(vc_r) == np.asarray(vc_p)).all(), (geom, t)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4, err_msg=str((geom, t)))


@settings(max_examples=40)
@given(geom=GEOMS, t=st.integers(1, 6), seed=st.integers(0, 10_000))
def test_rollback_leaves_no_live_garbage(geom, t, seed):
    """The speculative rollback contract, stated on the ring alone: insert
    T rows at pos, roll the pointer back to pos+e. Every slot holding one
    of the T-e rejected rows must either (a) reconstruct as invalid, or
    (b) reconstruct to a position <= pos+e-1 - window — strictly outside
    the window of every future query (positions >= pos+e) — provided the
    ring obeys the engine's allocation law ring >= window + T. The very
    next T-row insert then overwrites all of them before anything attends
    wider. Globals are exempt: a pinned slot is only garbage-free because
    pos >= num_global implies rejected rows never land in the pinned
    prefix region's *final* state (they are overwritten by the next step's
    insert at the same positions)."""
    t = min(t, geom.lookahead + 1)
    rng = np.random.RandomState(seed)
    g, ring = geom.num_global, geom.cap - geom.num_global
    assert ring >= geom.window + t       # the allocation law under test
    for pos in (g, geom.cap - 1, geom.cap + rng.randint(0, geom.cap),
                3 * geom.cap + rng.randint(0, geom.cap)):
        for e in range(0, t + 1):
            total = pos + e              # rolled-back pointer
            t_s, valid = ring_slot_positions(
                jnp.asarray([total]), geom.wcap, ring_cap=geom.cap,
                num_global=g)
            t_s, valid = np.asarray(t_s)[0], np.asarray(valid)[0]
            for j in range(e, t):        # the rejected rows
                p = pos + j
                slot = p if p < g else g + (p - g) % ring
                if slot < g:
                    continue             # overwritten in place next step
                if not valid[slot]:
                    continue
                # the slot is live under the rolled-back pointer: whoever
                # it claims to hold must be out-of-window for all future
                # queries (>= total)
                assert t_s[slot] <= total - 1 - geom.window, (
                    geom, t, pos, e, j, slot, t_s[slot])
