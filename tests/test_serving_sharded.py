"""Mesh-sharded serving: the sharded engine is the SAME function.

Subprocess with --xla_force_host_platform_device_count=4 (the
test_sharding_profiles.py pattern). The load-bearing claims:

  * a pure slot-parallel mesh (4x1, slot axis over 'data') is token-for-
    token IDENTICAL to the single-device engine for mixed greedy/sampled
    requests — every slot's math is device-local, so even the sampled rows
    must match bitwise,
  * the ring caches actually shard (slot dim over 'data', per-slot `step`
    riding the same axis — the rule this PR adds; silent replication is the
    failure mode these rules exist to prevent),
  * a 2x2 TP mesh (row-parallel psum splits a bf16 contraction => logits
    can move ~1 ulp) still reproduces every GREEDY row token-for-token and
    serves sampled rows to completion,
  * divisibility-aware admission: with 4 slots on a 4-way slot axis the
    scheduler trims prefill batches to quantum multiples.

Marked slow like every other subprocess suite, but still IN the CI fast
lane: ci.yml runs this file as its own step (no marker filter — CPU-only,
hypothesis-free), so a sharding regression is visible at a glance without
double-running it inside the `-m "not slow"` sweep.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
    import jax
    import numpy as np
    from repro.configs import get_smoke_config, with_swat
    from repro.core import model as Mod
    from repro.launch import mesh as mesh_lib
    from repro.serving.engine import Request, ServingEngine

    assert jax.device_count() == 4, jax.devices()
    cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (12, 30, 7, 18, 25, 10)]
    temps = [0.0, 1.5, 0.0, 2.5, 1.0, 0.0]   # mixed greedy / sampled
    budgets = [6, 9, 4, 7, 5, 8]

    def reqs():
        return [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                        temperature=temps[i]) for i in range(6)]

    def run(mesh, **kw):
        eng = ServingEngine(cfg, params, batch_slots=4, max_len=128,
                            scan_steps=4, seed=11, mesh=mesh, **kw)
        return eng, {r.rid: r.tokens for r in eng.run(reqs())}

    def axes_of(spec):
        flat = []
        for entry in tuple(spec):
            if entry is None:
                continue
            flat.extend((entry,) if isinstance(entry, str) else entry)
        return flat
"""


def test_slot_parallel_mesh_token_identical():
    """4x1 slot-parallel mesh == single-device engine, bitwise, including
    temperature>0 rows (all math is slot-local under pure data sharding)."""
    run_sub(COMMON + """
    _, base = run(None)
    eng, shard = run(mesh_lib.make_debug_mesh(4, 1))
    k = eng.caches["l0"]["k"]
    assert "data" in axes_of(k.sharding.spec), k.sharding.spec
    step_spec = tuple(eng.caches["l0"]["step"].sharding.spec)
    assert step_spec[1] == "data", step_spec   # per-slot step rides the slot axis
    assert eng.scheduler.slot_quantum == 4
    assert base == shard, (base, shard)
    print("ok slot-parallel identical")
    """)


def test_tp_mesh_sharded_and_deterministic():
    """2x2 data x model mesh: caches shard on BOTH axes, every request is
    served to its exact budget, and the run is bit-reproducible (two
    identical engines agree). Token-for-token parity with the single-device
    engine is NOT asserted here: row-parallel TP psums a bf16 contraction
    in a different order, so logits move ~1 ulp and near-tied argmax /
    categorical draws may legitimately flip — the exact-parity bar lives on
    the slot-parallel mesh above, where all math is slot-local."""
    run_sub(COMMON + """
    eng, shard = run(mesh_lib.make_debug_mesh(2, 2))
    k = eng.caches["l0"]["k"]
    spec = tuple(k.sharding.spec)
    assert spec[1] == "data", spec             # slot axis sharded
    assert "model" in axes_of(k.sharding.spec), spec
    for i in range(6):
        assert len(shard[i]) == budgets[i]
    _, again = run(mesh_lib.make_debug_mesh(2, 2))
    assert shard == again, (shard, again)
    print("ok tp sharded + deterministic")
    """)


def test_sharded_chunked_prefill_matches():
    """Chunked prefill under the slot-parallel mesh is still exact."""
    run_sub(COMMON + """
    mesh = mesh_lib.make_debug_mesh(4, 1)
    _, single = run(mesh)
    _, chunked = run(mesh, prefill_chunk=8)
    assert single == chunked, (single, chunked)
    print("ok sharded chunked prefill")
    """)


def test_speculative_slot_parallel_identical():
    """Speculative decode on a 4-device slot-parallel mesh: bitwise the
    unsharded speculative engine on EVERY row (draft/verify/accept and
    the n-gram history are all slot-local math), and the greedy rows are
    bitwise the unsharded SEQUENTIAL engine — the ISSUE-6 acceptance bar.
    The drafter history state must actually ride the slot axis, not
    silently replicate."""
    run_sub(COMMON + """
    _, seq = run(None)
    _, spec = run(None, speculative=3)
    eng, shard = run(mesh_lib.make_debug_mesh(4, 1), speculative=3)
    assert spec == shard, (spec, shard)
    for i in (0, 2, 5):                      # the greedy rows
        assert shard[i] == seq[i], (i, shard[i], seq[i])
    assert eng.stats["draft_proposed"] > 0
    print("ok sharded speculative identical; acceptance",
          round(eng.acceptance_rate, 3))
    """)


def test_metrics_slot_parallel_identical():
    """Device counters on the 4-device slot-parallel mesh — the ISSUE-10
    acceptance bar: the metrics-on engine is BITWISE identical to the
    metrics-off sharded run (sequential AND speculative), the counter
    vectors actually ride the slot axis (no silent replication), and the
    device totals agree with the host-side stats."""
    run_sub(COMMON + """
    _, base = run(mesh_lib.make_debug_mesh(4, 1))
    eng, out = run(mesh_lib.make_debug_mesh(4, 1), metrics=True)
    assert base == out, (base, out)
    mx = eng._mx
    assert "data" in axes_of(mx["tokens"].sharding.spec), \\
        mx["tokens"].sharding.spec
    dev = eng.device_metrics()
    assert dev["tokens"] == eng.stats["tokens_emitted"]
    assert dev["quarantined"] == 0

    _, sbase = run(mesh_lib.make_debug_mesh(4, 1), speculative=3)
    seng, sout = run(mesh_lib.make_debug_mesh(4, 1), speculative=3,
                     metrics=True)
    assert sbase == sout, (sbase, sout)
    sdev = seng.device_metrics()
    assert sdev["drafts_proposed"] == seng.stats["draft_proposed"] > 0
    assert sdev["drafts_accepted"] == seng.stats["draft_accepted"]
    print("ok sharded metrics identical; device tokens", dev["tokens"])
    """)


def test_chaos_quarantine_slot_parallel():
    """Fault injection on the 4-device slot-parallel mesh — the ISSUE-8
    acceptance bar: NaN-poisoning one slot's logits quarantines exactly
    that request (status 'poisoned', clean-prefix tokens) while every
    healthy slot stays BITWISE identical to the fault-free sharded run.
    The injected scan is still slot-local math, so the guarded program is
    held to the same zero-collective budget (launch/analyze chaos_4x1)."""
    run_sub(COMMON + """
    from repro.serving import faults as Flt
    from repro.serving.faults import FaultPlan

    _, base = run(mesh_lib.make_debug_mesh(4, 1))
    Flt.consume_events()
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=128,
                        scan_steps=4, seed=11,
                        mesh=mesh_lib.make_debug_mesh(4, 1),
                        faults=FaultPlan(poison_logits=((2, 3, "nan"),)))
    out = {r.rid: r for r in eng.run(reqs())}
    assert out[2].status == "poisoned", out[2]
    assert len(out[2].tokens) == 3 and out[2].tokens == base[2][:3]
    for i in (0, 1, 3, 4, 5):
        assert out[i].status == "ok" and out[i].tokens == base[i], i
    assert eng.stats["quarantined"] == 1
    kinds = [e["kind"] for e in Flt.consume_events()]
    assert "slot_quarantined" in kinds, kinds
    print("ok sharded chaos quarantine bitwise")
    """)
