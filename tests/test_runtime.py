"""Fault-tolerant runtime: failure injection -> restart -> bit-exact
continuation; straggler watchdog; loss actually decreases on the synthetic
language."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.types import AttentionSpec
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.runtime.trainer import StragglerWatchdog, TrainConfig, Trainer


def make_trainer(tmp_path, total=8, fail_at=-1, ckpt_every=4, seed=0,
                 compression=False):
    cfg = get_smoke_config("llama3p2_1b")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4, seed=7)
    tc = TrainConfig(total_steps=total, ckpt_every=ckpt_every,
                     ckpt_dir=str(tmp_path / "ckpt"), log_every=100,
                     seed=seed, fail_at_step=fail_at,
                     grad_compression=compression)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total)
    return Trainer(cfg, opt, tc, data_cfg)


def test_failure_injection_and_bitexact_resume(tmp_path):
    # uninterrupted run -> reference final params
    ref = make_trainer(tmp_path / "ref", total=8).train()

    # interrupted at step 6 (after the step-4 checkpoint)
    with pytest.raises(RuntimeError, match="injected failure"):
        make_trainer(tmp_path / "x", total=8, fail_at=6).train()
    # restart: must resume from step 4 and reach the same final state
    out = make_trainer(tmp_path / "x", total=8).train()

    for a, b in zip(jax.tree.leaves(ref["state"]["params"]),
                    jax.tree.leaves(out["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_loss_decreases(tmp_path):
    out = make_trainer(tmp_path, total=30, ckpt_every=100).train()
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_grad_compression_training_still_converges(tmp_path):
    out = make_trainer(tmp_path, total=30, ckpt_every=100,
                       compression=True).train()
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=3.0)
    for s in range(10):
        assert not w.record(s, 0.1)
    assert w.record(10, 1.0)      # 10x median -> flagged
    assert not w.record(11, 0.11)
    assert w.flagged and w.flagged[0][0] == 10
