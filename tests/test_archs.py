"""Per-architecture smoke tests: every assigned arch (reduced config of the
same family) runs one forward + one train step on CPU — output shapes and
finite values. Full configs are exercised only by the dry-run (deliverable e).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_config, get_smoke_config, with_swat
from repro.core import model as Mod
from repro.launch import specs as Sp
from repro.launch import steps as St
from repro.optim import adamw


def smoke_batch(cfg, rng, b=2, l=32):
    batch = {}
    if cfg.frontend == "vision":
        batch["embeddings"] = jnp.asarray(rng.randn(b, l, cfg.d_model),
                                          jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (b, l)), jnp.int32)
    if cfg.encoder_decoder:
        batch["enc_embeddings"] = jnp.asarray(
            rng.randn(b, 16, cfg.d_model), jnp.float32)
    batch["labels"] = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (b, l)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    batch = smoke_batch(cfg, rng)

    logits, aux = Mod.forward_logits(params, cfg, batch, remat=False)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch

    step = St.make_train_step(cfg, adamw.AdamWConfig(warmup_steps=1))
    opt = adamw.init_opt_state(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert float(metrics["grad_norm"]) > 0, arch
    # params actually moved
    delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ["llama3p2_1b", "gemma2_2b", "mamba2_1p3b",
                                  "granite_moe_1b", "whisper_tiny"])
def test_smoke_prefill_decode(arch, rng):
    cfg = get_smoke_config(arch)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    b, lp = 2, 16
    batch = smoke_batch(cfg, rng, b=b, l=lp)
    batch.pop("labels")
    logits, caches = Mod.prefill(params, cfg, batch, max_len=64)
    assert logits.shape == (b, 1, cfg.vocab_size)
    step_batch = {"tokens": jnp.asarray(rng.randint(
        0, cfg.vocab_size, (b, 1)), jnp.int32)}
    logits2, caches = Mod.decode_step(params, cfg, step_batch, caches)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


def test_full_config_params_match_expectation():
    """Full-size param counts are in the right ballpark for the named
    architectures (config-fidelity guard)."""
    expect = {
        "llama3p2_1b": (1.0e9, 1.8e9),
        "mamba2_1p3b": (1.0e9, 1.8e9),
        "internvl2_1b": (0.4e9, 1.2e9),  # LM backbone only (ViT is stubbed)
        "qwen2p5_32b": (28e9, 36e9),
        "granite_8b": (7e9, 9.5e9),
        "gemma2_2b": (2.0e9, 3.3e9),
        "whisper_tiny": (25e6, 80e6),
        "jamba_1p5_large": (350e9, 450e9),
        "granite_moe_1b": (1.0e9, 1.7e9),
        "moonshot_v1_16b": (14e9, 30e9),
    }
    for arch, (lo, hi) in expect.items():
        n = Sp.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_active_params_less_than_total_for_moe():
    for arch in ("granite_moe_1b", "moonshot_v1_16b", "jamba_1p5_large"):
        cfg = get_config(arch)
        assert Sp.active_param_count(cfg) < Sp.param_count(cfg)


def test_with_swat_variant():
    cfg = with_swat(get_config("llama3p2_1b"), window=2048, num_global=128)
    assert cfg.attention.kind == "swat"
    assert cfg.sub_quadratic
    # attention-free arch: no-op
    m = get_config("mamba2_1p3b")
    assert with_swat(m) is m


def test_sub_quadratic_flags():
    assert get_config("mamba2_1p3b").sub_quadratic
    assert not get_config("llama3p2_1b").sub_quadratic
    assert not get_config("gemma2_2b").sub_quadratic  # half the layers dense
    assert not get_config("jamba_1p5_large").sub_quadratic  # dense attn 1/8
