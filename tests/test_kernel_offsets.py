"""Offset (context-parallel hook) path of the Pallas kernels.

Strategy: compute the full (L) problem with the O(N^2) oracle, then ask the
kernel for a [q_off, q_off+lq) slice of rows given only the kv slice
[kv_off, kv_off+lkv) that covers those rows' bands — exactly what a CP shard
sees. Outputs must match the oracle's rows. Also: the kv_lo bound masks
"before sequence start" halo rows (leftmost-shard case).
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels import ops as kops
from repro.kernels import ref as R
from repro.kernels import swat_attention as F


def _mk(rng, b, h, l, d):
    return (jnp.asarray(rng.randn(b, h, l, d), jnp.float32) * 0.5
            for _ in range(1)).__next__()


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_offset_slice_matches_oracle(causal, impl):
    b, h, L, d, w = 1, 2, 256, 16, 32
    bq = bk = 16
    spec = AttentionSpec(kind="swat", window=w, causal=causal)
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    want = R.attention_ref(q, k, v, spec)

    q_off, lq = 64, 64
    kv_off, lkv = 32, 128          # covers [64-32, 128+32) for both masks
    qs = q[:, :, q_off:q_off + lq]
    ks = k[:, :, kv_off:kv_off + lkv]
    vs = v[:, :, kv_off:kv_off + lkv]
    pat = patterns.build_block_pattern(spec, lq, lkv, bq, bk,
                                       q_shift=q_off - kv_off)
    if impl == "pallas":
        got = F.swat_attention_fwd(qs, ks, vs, spec, pattern=pat,
                                   q_offset=q_off, kv_offset=kv_off,
                                   seq_kv_bound=L, interpret=True)
    else:
        got = kops._xla_banded(qs, ks, vs, spec, pat, d ** -0.5,
                               q_shift=q_off - kv_off)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want[:, :, q_off:q_off + lq]),
                               atol=2e-2, rtol=2e-2)


def test_offset_leftmost_shard_kv_lo():
    """Leftmost CP shard: the halo region is garbage (zeros from ppermute);
    kv_lo / negative global indices must mask it exactly."""
    b, h, L, d, w = 1, 2, 64, 8, 16
    bq = bk = 16
    spec = AttentionSpec(kind="swat", window=w, causal=True)
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    want = R.attention_ref(q, k, v, spec)

    halo = 16
    garbage = jnp.full((b, h, halo, d), 7.7, jnp.float32)  # worse than zeros
    k_ext = jnp.concatenate([garbage, k[:, :, :32]], axis=2)
    v_ext = jnp.concatenate([garbage, v[:, :, :32]], axis=2)
    qs = q[:, :, :32]
    pat = patterns.build_block_pattern(spec, 32, 32 + halo, bq, bk,
                                       q_shift=halo)
    # pallas path: kv_offset=-halo puts halo rows at negative global indices
    got = F.swat_attention_fwd(qs, k_ext, v_ext, spec, pattern=pat,
                               q_offset=0, kv_offset=-halo,
                               seq_kv_bound=L, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, :, :32]),
                               atol=2e-2, rtol=2e-2)
    # xla path: traced kv_lo bound
    got2 = kops._xla_banded(qs, k_ext, v_ext, spec, pat, d ** -0.5,
                            q_shift=halo, kv_lo=jnp.asarray(halo),
                            kv_hi=jnp.asarray(32 + halo))
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want[:, :, :32]),
                               atol=2e-2, rtol=2e-2)


@settings(max_examples=10, deadline=None)
@given(w=st.sampled_from([8, 16, 24]),
       causal=st.booleans(),
       seed=st.integers(0, 3))
def test_offset_partials_merge_to_full_softmax(w, causal, seed):
    """Splitting one row's band across two kv buffers and lse-merging the
    partials must reproduce the unsplit softmax (the CP merge identity)."""
    from repro.distributed.context_parallel import _merge, _finalize
    b, h, L, d = 1, 1, 64, 8
    spec = AttentionSpec(kind="swat", window=w, causal=causal)
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    k = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    v = jnp.asarray(rng.randn(b, h, L, d), jnp.float32) * 0.5
    want = R.attention_ref(q, k, v, spec)

    bq = bk = 16
    half = 32
    # partial 1: kv buffer [0, 32); partial 2: kv buffer [32, 64)
    p1 = kops._xla_banded(q, k[:, :, :half], v[:, :, :half], spec,
                          patterns.build_block_pattern(spec, L, half, bq, bk),
                          d ** -0.5, return_partials=True)
    pat2 = patterns.build_block_pattern(spec, L, half, bq, bk,
                                        q_shift=-half)
    p2 = kops._xla_banded(q, k[:, :, half:], v[:, :, half:], spec, pat2,
                          d ** -0.5, q_shift=-half, return_partials=True)
    out = _finalize(_merge(p1, p2), q.dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-2, rtol=2e-2)
