"""Multi-device SPMD tests (subprocess: device count must be set before jax
imports). A reduced config exercises the exact dry-run path — sharding
rules, lower, compile, roofline record — on a 2x2 mesh; plus a real
sharded train step executes and matches the single-device result."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{ROOT}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_debug_mesh_train_step_matches_single_device():
    """The sharded train step computes the same loss as unsharded."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.core import model as Mod
        from repro.distributed import sharding as Sh
        from repro.launch import mesh as mesh_lib, steps as St
        from repro.optim import adamw

        assert len(jax.devices()) == 4
        cfg = get_smoke_config("llama3p2_1b")
        mesh = mesh_lib.make_debug_mesh(2, 2)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size,
                                                   (4, 32)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        params = Mod.init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_opt_state(params)
        opt_cfg = adamw.AdamWConfig(warmup_steps=1)

        # single-device reference
        step0 = jax.jit(St.make_train_step(cfg, opt_cfg))
        _, _, m0 = step0(params, opt, batch)

        with mesh:
            p_specs = jax.eval_shape(
                lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
            p_sh = Sh.param_sharding(p_specs, mesh)
            o_sh = adamw.OptState(step=Sh.replicated(mesh), mu=p_sh, nu=p_sh)
            b_sh = Sh.batch_sharding(batch, mesh)
            act = jax.sharding.NamedSharding(mesh, Sh.activation_spec(mesh))
            step1 = jax.jit(St.make_train_step(cfg, opt_cfg,
                                               act_sharding=act),
                            in_shardings=(p_sh, o_sh, b_sh),
                            out_shardings=(p_sh, o_sh, None))
            params_s = jax.device_put(params, p_sh)
            opt_s = jax.device_put(opt, o_sh)
            batch_s = jax.device_put(batch, b_sh)
            _, _, m1 = step1(params_s, opt_s, batch_s)
        d = abs(float(m0["loss"]) - float(m1["loss"]))
        print("LOSS_DELTA", d)
        assert d < 1e-3, d
    """)
    assert "LOSS_DELTA" in out


@pytest.mark.slow
def test_dryrun_cell_on_debug_mesh():
    """The dry-run machinery (lower+compile+roofline record) works end to
    end on a small mesh for train, prefill AND decode modes."""
    out = run_sub("""
        import jax
        from repro.configs import get_smoke_config
        from repro.core.types import ShapeConfig
        from repro.distributed import hlo_analysis as H
        from repro.distributed import sharding as Sh
        from repro.launch import mesh as mesh_lib
        from repro.launch.dryrun import lower_cell

        cfg = get_smoke_config("gemma2_2b")
        mesh = mesh_lib.make_debug_mesh(2, 2)
        for shape in (ShapeConfig("t", 64, 8, "train"),
                      ShapeConfig("p", 64, 8, "prefill"),
                      ShapeConfig("d", 64, 8, "decode")):
            with mesh:
                compiled, lowered = lower_cell(cfg, shape, mesh)
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            coll = H.parse_collectives(compiled.as_text())
            roof = H.roofline_terms(cost, coll, 1e9)
            assert roof.flops > 0
            print("MODE_OK", shape.mode, roof.dominant)
    """)
    assert out.count("MODE_OK") == 3


@pytest.mark.slow
def test_elastic_checkpoint_across_meshes():
    """Checkpoint written from a 2x2 mesh restores onto a 4x1 mesh
    (different device layout) with identical values — elastic restart."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs import get_smoke_config
        from repro.core import model as Mod
        from repro.distributed import sharding as Sh
        from repro.launch import mesh as mesh_lib
        import tempfile

        cfg = get_smoke_config("granite_moe_1b")
        params = Mod.init_model(jax.random.PRNGKey(0), cfg)
        d = tempfile.mkdtemp()
        m = CheckpointManager(d, keep=1)

        mesh_a = mesh_lib.make_debug_mesh(2, 2)
        p_specs = jax.eval_shape(
            lambda: Mod.init_model(jax.random.PRNGKey(0), cfg))
        sh_a = Sh.param_sharding(p_specs, mesh_a)
        params_a = jax.device_put(params, sh_a)
        m.save(1, params_a, blocking=True)

        mesh_b = mesh_lib.make_debug_mesh(4, 1)
        sh_b = Sh.param_sharding(p_specs, mesh_b)
        params_b = m.restore(1, like=params, sharding=sh_b)
        for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(params_b)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
