"""Resilient serving: fault injection, slot quarantine, degradation ladder.

The contract under chaos (src/repro/serving/faults.py drives it):

  * a slot whose logits go non-finite is QUARANTINED — finalized as status
    'poisoned' with exactly the tokens emitted before the fault, the slot
    freed and refilled — while every healthy slot's tokens stay BITWISE
    identical to a fault-free run (the guards are row-wise and always
    compiled; injection only compiles when a plan asks for it),
  * sequential and speculative engines agree on the poisoned request's
    exact kept-token count (per-position injection in the verify window),
  * a Pallas dispatch failure degrades to the reference impl with a
    one-time warning and the batch still completes (status 'ok'),
  * malformed prompts are per-request rejections, never batch killers,
  * deadlines, bounded-queue backpressure and the speculative acceptance
    ladder all finalize with honest statuses instead of raising.

Everything here runs on the CPU smoke config; the 4-device slot-parallel
chaos case lives in test_serving_sharded.py with the other mesh suites.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config, with_swat
from repro.core import model as Mod
from repro.serving import faults as F
from repro.serving.engine import Request, Result, ServingEngine, STATUSES
from repro.serving.faults import FaultPlan, KernelDispatchError


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def swat_setup():
    cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(autouse=True)
def _clean_event_bus():
    """Each test sees only its own degradation events."""
    F.consume_events()
    yield
    F.consume_events()


def mkreqs(cfg, n=3, m=10, plen=12):
    return [Request(rid=i, prompt=np.random.RandomState(i).randint(
                0, cfg.vocab_size, (plen,)).astype(np.int32),
                max_new_tokens=m) for i in range(n)]


def by_rid(results):
    return {r.rid: r for r in results}


# ------------------------------------------------ logit poison / quarantine


def test_statuses_taxonomy():
    assert STATUSES == ("ok", "rejected", "poisoned", "deadline", "failed")
    assert Result(rid=0, tokens=[1]).ok
    assert not Result(rid=0, tokens=[], status="rejected", reason="x").ok


def test_clean_run_emits_no_events_and_default_plan_is_inert(setup):
    cfg, params = setup
    plan = FaultPlan()
    assert not plan.any and not plan.has_logit_faults
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64, scan_steps=4)
    out = eng.run(mkreqs(cfg))
    assert all(r.status == "ok" and r.reason == "" for r in out)
    assert F.consume_events() == []
    assert eng.stats["quarantined"] == 0


def test_nan_quarantine_healthy_slots_bitwise(setup):
    """Poison slot 0's logits at token index 4: that request finalizes as
    'poisoned' with EXACTLY its 4 clean tokens (a prefix of its fault-free
    output), the slot frees and serves the next request clean (a fault
    entry targets one occupant), and the co-batched requests — including
    the one reusing the quarantined slot — are bitwise the fault-free
    run."""
    cfg, params = setup
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          scan_steps=4)
    ref = by_rid(clean.run(mkreqs(cfg)))

    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64, scan_steps=4,
                        faults=FaultPlan(poison_logits=((0, 4, "nan"),)))
    out = by_rid(eng.run(mkreqs(cfg)))
    assert out[0].status == "poisoned" and len(out[0].tokens) == 4
    assert "quarantined" in out[0].reason
    assert out[0].tokens == ref[0].tokens[:4]
    assert out[1].status == "ok" and out[1].tokens == ref[1].tokens
    # rid 2 refills the quarantined slot and must decode clean + identical
    assert out[2].status == "ok" and out[2].tokens == ref[2].tokens
    assert eng.stats["quarantined"] == 1
    kinds = [e["kind"] for e in F.consume_events()]
    assert kinds == ["slot_quarantined"]


def test_inf_quarantine_pallas_impl(swat_setup):
    """Same quarantine contract on the Pallas decode path (+inf flavor)."""
    cfg, params = swat_setup
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          scan_steps=2, decode_impl="pallas")
    ref = by_rid(clean.run(mkreqs(cfg, m=8)))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64, scan_steps=2,
                        decode_impl="pallas",
                        faults=FaultPlan(poison_logits=((1, 3, "inf"),)))
    out = by_rid(eng.run(mkreqs(cfg, m=8)))
    assert out[1].status == "poisoned" and len(out[1].tokens) == 3
    assert out[1].tokens == ref[1].tokens[:3]
    for i in (0, 2):
        assert out[i].status == "ok" and out[i].tokens == ref[i].tokens


def test_spec_quarantine_exact_count_parity(setup):
    """Speculative injection is per verify-POSITION, so the poisoned
    request keeps exactly target_idx tokens — the same count the
    sequential engine keeps for the same plan."""
    cfg, params = setup
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          scan_steps=4, speculative=2)
    ref = by_rid(clean.run(mkreqs(cfg)))
    plan = FaultPlan(poison_logits=((1, 6, "nan"),))
    spec = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                         scan_steps=4, speculative=2, faults=plan)
    seq = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=4, faults=plan)
    for eng in (spec, seq):
        out = by_rid(eng.run(mkreqs(cfg)))
        assert out[1].status == "poisoned" and len(out[1].tokens) == 6
        assert out[1].tokens == ref[1].tokens[:6]
        assert out[0].tokens == ref[0].tokens
        assert out[2].tokens == ref[2].tokens


def test_corrupt_drafts_token_identical(setup):
    """Out-of-vocab drafter proposals are sanitized and simply fail
    verification: zero acceptance, but token-for-token the clean spec run
    (which itself is token-for-token the sequential engine)."""
    cfg, params = setup
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          scan_steps=4, speculative=2)
    ref = by_rid(clean.run(mkreqs(cfg)))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64, scan_steps=4,
                        speculative=2,
                        faults=FaultPlan(corrupt_draft_slots=(0, 1)))
    out = by_rid(eng.run(mkreqs(cfg)))
    for i in range(3):
        assert out[i].status == "ok" and out[i].tokens == ref[i].tokens
    assert eng.stats["draft_accepted"] == 0
    assert clean.stats["draft_accepted"] > 0


def test_cache_poison_quarantine(setup):
    """NaN-ing a slot's ring K cache rows between blocks surfaces as
    non-finite logits on its next step -> quarantined, healthy slots
    bitwise clean."""
    cfg, params = setup
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          scan_steps=4)
    ref = by_rid(clean.run(mkreqs(cfg)))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64, scan_steps=4,
                        faults=FaultPlan(poison_cache=((0, 3),)))
    out = by_rid(eng.run(mkreqs(cfg)))
    assert out[0].status == "poisoned"
    # poison applies at a block boundary after >= 3 tokens; everything the
    # slot emitted before it must be a clean prefix
    assert 3 <= len(out[0].tokens) < 10
    assert out[0].tokens == ref[0].tokens[:len(out[0].tokens)]
    assert out[1].status == "ok" and out[1].tokens == ref[1].tokens
    assert out[2].status == "ok" and out[2].tokens == ref[2].tokens
    kinds = [e["kind"] for e in F.consume_events()]
    assert "cache_poisoned" in kinds and "slot_quarantined" in kinds


# ------------------------------------------------- degradation ladder


def test_pallas_dispatch_failure_falls_back_to_ref(swat_setup):
    """An injected Pallas dispatch failure must not kill the batch: the
    engine recompiles with the reference impl, warns once, and the results
    are token-for-token the ref engine's."""
    cfg, params = swat_setup
    ref_eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                            scan_steps=2, decode_impl="ref")
    ref = by_rid(ref_eng.run(mkreqs(cfg, m=8)))
    F.consume_events()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                                scan_steps=2, decode_impl="pallas",
                                faults=FaultPlan(fail_pallas_dispatch=True))
            out = by_rid(eng.run(mkreqs(cfg, m=8)))
    finally:
        F.clear_kernel_failure()
    assert eng.decode_impl == "ref"
    assert eng.stats["kernel_fallbacks"] == 1
    for i in range(3):
        assert out[i].status == "ok" and out[i].tokens == ref[i].tokens
    pallas_warnings = [x for x in w
                       if "pallas" in str(x.message).lower()]
    assert len(pallas_warnings) == 1, "fallback warning must be one-time"
    assert "pallas_fallback" in [e["kind"] for e in F.consume_events()]


def test_kernel_failure_primitive_arms_and_clears():
    """The injection primitive itself: armed -> swat_decode raises
    KernelDispatchError before touching its operands (trace time, so the
    engine's donated caches are never consumed); cleared -> inert."""
    from repro.kernels import swat_decode as K
    F.install_kernel_failure()
    try:
        with pytest.raises(KernelDispatchError, match="injected"):
            K.swat_decode(None, None, None, None)
    finally:
        F.clear_kernel_failure()
    assert K._FORCE_FAIL is False


def test_spec_autodisable_and_probe_resume(setup):
    """Random prompts give the n-gram drafter ~zero acceptance: the ladder
    must auto-disable speculation once the windowed rate drops below
    threshold, probe again after spec_retry_blocks sequential blocks, and
    keep output token-identical to the sequential engine throughout."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                        scan_steps=4, speculative=2,
                        spec_min_acceptance=0.95,
                        spec_acceptance_window=2,
                        spec_retry_blocks=2,
                        spec_resume_acceptance=0.0)
    out = by_rid(eng.run(mkreqs(cfg, n=2, m=40)))
    assert all(r.status == "ok" and len(r.tokens) == 40
               for r in out.values())
    assert eng.stats["spec_autodisable"] >= 1
    # spec_resume_acceptance=0.0 makes every probe succeed -> the ladder
    # exercised the full off->probe->on->off cycle at least once
    assert eng.stats["spec_resume"] >= 1
    kinds = [e["kind"] for e in F.consume_events()]
    assert "spec_autodisable" in kinds and "spec_resume" in kinds

    seq = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                        scan_steps=4)
    ref = by_rid(seq.run(mkreqs(cfg, n=2, m=40)))
    for i in range(2):
        assert out[i].tokens == ref[i].tokens


# -------------------------------------------- admission / queue resilience


def test_malformed_prompts_rejected_per_request(setup):
    """Every malformed flavor the harness generates (empty, out-of-vocab,
    negative ids, oversized) finalizes as status 'rejected' with a reason
    naming the flavor — and the healthy requests around them serve to
    completion."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=2, max_prompt_len=32)
    bad = F.malformed_prompts(cfg.vocab_size, oversize=40)
    assert len(bad) >= 4
    reqs = mkreqs(cfg, n=2, m=4)
    reqs += [Request(rid=10 + j, prompt=p, max_new_tokens=4)
             for j, (p, _) in enumerate(bad)]
    out = by_rid(eng.run(reqs))
    assert out[0].status == "ok" and out[1].status == "ok"
    for j, (_, flavor) in enumerate(bad):
        r = out[10 + j]
        assert r.status == "rejected" and r.tokens == []
        assert flavor in r.reason, (flavor, r.reason)
    assert eng.stats["rejected"] == len(bad)
    kinds = [e["kind"] for e in F.consume_events()]
    assert kinds.count("request_rejected") == len(bad)


def test_oversized_prompt_admissible_by_default(setup):
    """max_prompt_len is opt-in: without it, long prompts stay admissible
    (ring prefill serves them exactly — only the last window survives)."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64, scan_steps=2)
    long_prompt = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (80,)).astype(np.int32)
    out = eng.run([Request(rid=0, prompt=long_prompt, max_new_tokens=4)])
    assert out[0].status == "ok" and len(out[0].tokens) == 4


def test_backpressure_bounded_queue(setup):
    """Beyond max_pending queued requests the tail sheds as 'rejected'
    (queue overflow) instead of buffering without bound — FCFS head
    still serves."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        scan_steps=2, max_pending=3)
    out = by_rid(eng.run(mkreqs(cfg, n=8, m=4)))
    statuses = [out[i].status for i in range(8)]
    assert statuses == ["ok"] * 3 + ["rejected"] * 5
    for i in range(3, 8):
        assert "queue overflow" in out[i].reason
    assert eng.stats["rejected"] == 5


def test_deadline_expires_queued_request(setup):
    """A queued request whose deadline lapses before a slot frees
    finalizes as 'deadline' with zero tokens; the batch ahead of it is
    untouched."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64, scan_steps=2)
    reqs = mkreqs(cfg, n=2, m=6)
    reqs[1] = Request(rid=1, prompt=reqs[1].prompt, max_new_tokens=6,
                      deadline=1e-9)
    out = by_rid(eng.run(reqs))
    assert out[0].status == "ok" and len(out[0].tokens) == 6
    assert out[1].status == "deadline" and out[1].tokens == []
    assert eng.stats["deadline"] == 1
    assert "deadline_expired" in [e["kind"] for e in F.consume_events()]


def test_deadline_expires_live_slot_with_partial_tokens(setup, monkeypatch):
    """A live slot past its deadline finalizes with whatever it emitted
    (status 'deadline'), freeing the slot at the next block boundary.
    Deterministic: the engine's clock is faked to advance a fixed step per
    reading, so the test never races real decode speed."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=128,
                        scan_steps=2)
    now = {"t": 0.0}

    class _Clock:
        @staticmethod
        def monotonic():
            now["t"] += 0.1
            return now["t"]

    monkeypatch.setattr("repro.serving.engine.time", _Clock)
    prompt = np.random.RandomState(7).randint(
        0, cfg.vocab_size, (12,)).astype(np.int32)
    # clock advances 0.1 per reading (one per run-loop iteration), so a
    # 0.35 deadline lapses after a few 2-step blocks, far short of 400
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=400,
                           deadline=0.35)])
    assert out[0].status == "deadline"
    assert "deadline" in out[0].reason
    assert 0 < len(out[0].tokens) < 400
    assert eng.stats["deadline"] == 1
    assert eng.slot_free == [True]       # slot actually freed for reuse
    assert "deadline_expired" in [e["kind"] for e in F.consume_events()]


def test_run_flushes_completed_results_on_exception(setup):
    """The satellite bugfix: results finished before a mid-loop exception
    must survive it. The seed kept them in a local list that the raise
    threw away; now they land in the engine the moment they finalize and
    `take_completed()` recovers them."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64, scan_steps=2)
    calls = {"n": 0}
    real_plan = eng.scheduler.plan

    def exploding_plan(pending, num_free):
        calls["n"] += 1
        if calls["n"] > 1:
            raise RuntimeError("scheduler exploded mid-run")
        return real_plan(pending, num_free)

    eng.scheduler.plan = exploding_plan
    with pytest.raises(RuntimeError, match="exploded"):
        eng.run(mkreqs(cfg, n=2, m=4))
    rescued = eng.take_completed()
    assert [r.rid for r in rescued] == [0]
    assert rescued[0].status == "ok" and len(rescued[0].tokens) == 4
    F.consume_events()
