"""Data pipeline determinism + shard consistency (restart/elastic safety)."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def make(bs=8, seed=3):
    return SyntheticLM(DataConfig(vocab_size=97, seq_len=64,
                                  global_batch=bs, seed=seed))


def test_deterministic_by_step():
    a, b = make(), make()
    for step in (0, 5, 1000):
        x, y = a.global_batch(step), b.global_batch(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_steps_differ():
    ds = make()
    assert not np.array_equal(ds.global_batch(1)["tokens"],
                              ds.global_batch(2)["tokens"])


def test_shards_partition_global_batch():
    ds = make(bs=8)
    g = ds.global_batch(3)["tokens"]
    parts = [ds.shard_batch(3, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), g)


def test_copy_structure_present():
    """The synthetic language embeds copy spans (so window attention has
    something local to learn)."""
    ds = make()
    t = ds.global_batch(0)["tokens"]
    span = ds.cfg.copy_span
    np.testing.assert_array_equal(t[:, span:2 * span], t[:, :span])


def test_tokens_in_range():
    t = make().global_batch(9)["tokens"]
    assert t.min() >= 0 and t.max() < 97
