"""swatlint rule suite: every rule family has a deliberately-broken toy
that trips EXACTLY its rule, and a known-good twin asserted clean.

Covers (ISSUE 7):
  * donation/aliasing      — un-donated large carry caught; donated twin
                             proven aliased in the compiled executable
  * host-sync              — callback inside lax.scan caught
  * dtype promotion        — bf16->f32 upcast feeding a matmul caught
  * collective budget      — slot-axis reduction under a forced 4-device
                             mesh caught (subprocess); async -start/-done
                             HLO double-count regression
  * recompile audit        — weak-type leak + lowering-count cap
  * engine integration     — default engine clean, donate=False engine is
                             the known-bad fixture; pad-fallback events
                             become warn findings
  * baselines              — diff/check_artifact gate semantics
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import baselines, report as Rep, rules as R, tracer as T
from repro.distributed.hlo_analysis import (CollectiveBudget, check_budget,
                                            parse_collectives)

ROOT = Path(__file__).resolve().parents[1]


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def point(fn, args, carries=(), tags=frozenset(), name="toy"):
    return T.EntryPoint(name=name, family=name, fn=fn, args=args,
                        carries=carries, tags=tags)


# ------------------------------------------------------------- donation --

BIG = sds((512, 512))            # 1 MiB: over the generic threshold


def test_undonated_carry_is_caught():
    fn = jax.jit(lambda c, x: (c + x, x.sum()))
    tr = T.trace(point(fn, (BIG, BIG), carries=(0,)))
    f = R.check_donation(tr)
    assert any(x.rule == "donation" and x.severity == "error" for x in f)
    assert not R.check_host_sync(tr) and not R.check_dtype_promotion(tr)


def test_donated_carry_is_clean_and_aliased():
    fn = jax.jit(lambda c, x: (c + x, x.sum()), donate_argnums=(0,))
    tr = T.trace(point(fn, (BIG, BIG), carries=(0,)))
    assert R.check_donation(tr) == []
    # the proof the ISSUE asks for: donation survived into the compiled
    # executable's input-output alias table, not just the StableHLO marker
    assert tr.alias_pairs, "compiled executable has no input-output alias"


def test_forgotten_carry_generic_warn():
    # nobody declared carries, but a 1 MiB input round-trips to an output
    # of the same shape/dtype: the generic rule flags it warn-level
    fn = jax.jit(lambda c, x: (c * 2.0, x.sum()))
    tr = T.trace(point(fn, (BIG, BIG)))
    f = R.check_donation(tr)
    assert any(x.rule == "donation" and x.severity == "warn" for x in f)


def test_pruned_unused_leaf_not_flagged():
    # an unused carry leaf is pruned from the lowering (keep_unused=False);
    # that must count as "nothing copied", not as a dropped donation
    fn = jax.jit(lambda c, x: (c[0] + x, x.sum()), donate_argnums=(0,))
    tr = T.trace(point(fn, ((BIG, sds((8, 8))), BIG), carries=(0,)))
    assert R.check_donation(tr) == []


# ------------------------------------------------------------ host sync --

def test_callback_inside_scan_is_caught():
    def bad(x):
        def body(c, _):
            c = jax.pure_callback(lambda a: np.asarray(a),
                                  jax.ShapeDtypeStruct(x.shape, x.dtype), c)
            return c, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    tr = T.trace(point(jax.jit(bad), (sds((8,)),)), compile=False)
    f = R.check_host_sync(tr)
    assert any(x.rule == "host_sync" and x.severity == "error"
               and "scan" in x.details["context"] for x in f)
    assert not R.check_dtype_promotion(tr)


def test_callback_on_hot_path_is_error_even_at_top_level():
    def bad(x):
        return jax.pure_callback(lambda a: np.asarray(a),
                                 jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    tr = T.trace(point(jax.jit(bad), (sds((8,)),),
                       tags=frozenset({"decode_hot_path"})), compile=False)
    assert any(x.severity == "error" for x in R.check_host_sync(tr))


def test_clean_scan_no_host_sync():
    def good(x):
        out, _ = jax.lax.scan(lambda c, _: (c * 2, None), x, None, length=3)
        return out
    tr = T.trace(point(jax.jit(good), (sds((8,)),)), compile=False)
    assert R.check_host_sync(tr) == []


# ------------------------------------------------------ dtype promotion --

def test_bf16_upcast_matmul_is_caught():
    def bad(a, b):
        return a.astype(jnp.float32) @ b.astype(jnp.float32)
    tr = T.trace(point(jax.jit(bad), (sds((16, 16), jnp.bfloat16),
                                      sds((16, 16), jnp.bfloat16))),
                 compile=False)
    f = R.check_dtype_promotion(tr)
    assert any(x.rule == "dtype_promotion" for x in f)


def test_bf16_native_matmul_is_clean():
    # staying bf16 — or asking for f32 ACCUMULATION via
    # preferred_element_type — involves no convert and must not trip
    def good(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    tr = T.trace(point(jax.jit(good), (sds((16, 16), jnp.bfloat16),
                                      sds((16, 16), jnp.bfloat16))),
                 compile=False)
    assert R.check_dtype_promotion(tr) == []


# ---------------------------------------------- hlo_analysis regression --

_ASYNC_HLO = """
ENTRY %main {
  %p0 = f32[128]{0} parameter(0)
  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(f32[128]{0} %p0)
  %ard = f32[128]{0} all-reduce-done((f32[128]{0}, f32[128]{0}) %ars)
  %sync = f32[128]{0} all-reduce(f32[128]{0} %ard)
  %ag = (f32[32]{0}, f32[128]{0}) all-gather-start(f32[32]{0} %p1)
  %agd = f32[128]{0} all-gather-done((f32[32]{0}, f32[128]{0}) %ag)
}
"""


def test_async_start_done_counted_once():
    """-start/-done pairs are ONE collective, and a start's tuple result
    (operand-alias, result) must not double its bytes: one async and one
    sync all-reduce of the same shape cost the same."""
    st = parse_collectives(_ASYNC_HLO)
    assert st.counts == {"all-reduce": 2, "all-gather": 1}
    assert st.bytes_by_kind["all-reduce"] == 2 * 128 * 4
    assert st.bytes_by_kind["all-gather"] == 128 * 4
    assert st.wire_bytes == 2.0 * 2 * 128 * 4 + 128 * 4


def test_collective_budget_check():
    st = parse_collectives(_ASYNC_HLO)
    free = CollectiveBudget.collective_free()
    viol = check_budget(st, free)
    assert len(viol) == 2 and all("collective-free" in v for v in viol)
    blessed = CollectiveBudget.from_counts(st.counts, st.wire_bytes)
    assert check_budget(st, blessed) == []
    tight = CollectiveBudget(allow=(("all-gather", 1), ("all-reduce", 1)),
                             max_wire_bytes=1.0)
    viol = check_budget(st, tight)
    assert any("wire bytes" in v for v in viol)
    assert any("budget allows 1" in v for v in viol)


# ------------------------------------------------------ recompile audit --

def test_weak_type_and_lowering_cap():
    fn = jax.jit(lambda x: x * 2)
    tr_a = T.trace(point(fn, (sds((4,)),), name="fam"), compile=False)
    tr_b = T.trace(point(fn, (sds((8,)),), name="fam"), compile=False)
    assert tr_a.compile_key != tr_b.compile_key
    f = R.audit_recompiles([tr_a, tr_b], max_per_family={"fam": 1})
    assert any(x.rule == "recompile" and x.severity == "error" for x in f)
    assert R.audit_recompiles([tr_a, tr_b], max_per_family={"fam": 2}) == []
    # weak-typed scalar leaks fork compile keys for identical compute
    weak = jax.eval_shape(lambda: jnp.asarray(1.0) * 1.0)
    trw = T.trace(point(jax.jit(lambda x: x + 0.0), (weak,), name="w"),
                  compile=False)
    if any(l.weak_type for l in trw.in_leaves):
        assert any(x.rule == "recompile" and x.severity == "warn"
                   for x in R.audit_recompiles([trw]))


# ------------------------------------------------------- pad fallback --

def test_pad_event_becomes_warn_finding():
    from repro.kernels import swat_decode
    swat_decode.consume_pad_events()
    swat_decode._warn_pad(17, 16, 16)
    events = swat_decode.consume_pad_events()
    assert events and events[0]["w"] == 17
    assert events[0]["chosen_block"] == 16
    assert swat_decode.consume_pad_events() == []      # drained
    rep = Rep.analyze_entry_points([], pad_events=events, label="kern")
    assert rep["summary"]["warnings"] == 1
    assert rep["findings"][0]["rule"] == "pad_fallback"
    assert "16" in rep["findings"][0]["message"]       # names the block


def test_paged_gather_event_becomes_warn_finding():
    from repro.kernels import swat_decode
    swat_decode.consume_pad_events()
    swat_decode.record_paged_fallback(nb=4, page=16,
                                      reason="table resolved outside kernel")
    events = swat_decode.consume_pad_events()
    assert events and events[0]["kind"] == "paged_gather"
    rep = Rep.analyze_entry_points([], pad_events=events, label="kern")
    assert rep["summary"]["warnings"] == 1
    assert rep["findings"][0]["rule"] == "paged_gather_fallback"


# -------------------------------------------------- engine integration --

@pytest.fixture(scope="module")
def engine_pair():
    from repro.configs import get_smoke_config
    from repro.core import model as Mod
    from repro.serving.engine import ServingEngine
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    mk = lambda donate: ServingEngine(cfg, params, batch_slots=2,
                                      max_len=128, scan_steps=2,
                                      donate=donate)
    return mk(True), mk(False)


def scan_entries(engine):
    return [p for p in T.engine_entry_points(
                engine, batch_sizes=[1], scan_lens=[2])
            if p.family in ("scan", "cache_insert")]


def test_engine_hot_path_clean(engine_pair):
    good, _ = engine_pair
    rep = Rep.analyze_entry_points(scan_entries(good))
    assert rep["summary"]["errors"] == 0, rep["findings"]
    scan = next(v for k, v in rep["entries"].items() if k.startswith("scan"))
    assert scan["carries_donated"] is True
    assert scan["alias_pairs"] > 0          # aliased in compiled executable
    assert scan["collectives"] == {}


def test_engine_without_donation_is_caught(engine_pair):
    _, bad = engine_pair
    rep = Rep.analyze_entry_points(scan_entries(bad))
    assert rep["summary"]["errors"] > 0
    rules = {f["rule"] for f in rep["findings"]
             if f["severity"] == "error"}
    assert rules == {"donation"}


# ------------------------------------------------------------ baselines --

def _fake_report(errors=0, warns=0, lowerings=None):
    findings = ([{"rule": "donation", "severity": "error", "entry": "e",
                  "message": "m", "details": {}}] * errors
                + [{"rule": "host_sync", "severity": "warn", "entry": "e",
                    "message": "m", "details": {}}] * warns)
    return {"swatlint": 1, "meta": {},
            "engines": {"single": {"entries": {},
                                   "lowerings": lowerings or {"scan": 1},
                                   "budgets": {}, "findings": findings,
                                   "summary": {"errors": errors,
                                               "warnings": warns,
                                               "entries": 0}}},
            "summary": {"errors": errors, "warnings": warns, "entries": 0}}


def test_baseline_diff_gates():
    base = _fake_report()
    assert baselines.diff(_fake_report(), base) == []
    assert any("donation" in v for v in
               baselines.diff(_fake_report(errors=1), base))
    assert any("warning count" in v for v in
               baselines.diff(_fake_report(warns=1), base))
    assert any("lowerings" in v for v in
               baselines.diff(_fake_report(lowerings={"scan": 2}), base))
    # warn count may also SHRINK freely
    assert baselines.diff(_fake_report(), _fake_report(warns=3)) == []


def test_check_artifact_gate(tmp_path):
    p = tmp_path / "A.json"
    with pytest.raises(AssertionError):
        baselines.check_artifact(str(p))
    baselines.save(_fake_report(), str(p))
    assert baselines.check_artifact(str(p))["summary"]["errors"] == 0
    baselines.save(_fake_report(errors=2), str(p))
    with pytest.raises(AssertionError):
        baselines.check_artifact(str(p))


def test_committed_baseline_is_clean():
    rep = baselines.check_artifact()          # the committed ANALYSIS.json
    assert rep["swatlint"] == 1
    # the tentpole acceptance claims, as recorded in the artifact:
    engines = rep["engines"]
    for label in ("single", "slot_parallel_4x1", "tp_2x2"):
        assert label in engines
    for name, e in engines["slot_parallel_4x1"]["entries"].items():
        if "decode_hot_path" in e["tags"]:
            assert e["collectives"] == {}, (name, e)
            assert e["carries_donated"] and e["alias_pairs"] > 0


# ------------------------------------------- forced-mesh collective toy --

@pytest.mark.slow
def test_slot_axis_allgather_caught_under_mesh():
    """A deliberate slot-axis reduction sharded over 4 forced CPU devices
    trips the collective-free budget; the engine decode scan on the same
    mesh stays clean (subprocess: device count must be set pre-import)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import parse_mesh
        from repro.analysis import rules as R, tracer as T
        from repro.distributed.hlo_analysis import CollectiveBudget

        mesh = parse_mesh("4x1")
        sh = NamedSharding(mesh, P("data"))
        fn = jax.jit(lambda x: x - x.mean(), in_shardings=(sh,),
                     out_shardings=sh)
        pt = T.EntryPoint(
            name="toy_mean", family="toy_mean", fn=fn,
            args=(jax.ShapeDtypeStruct((4, 64), jnp.float32),),
            tags=frozenset({"slot_parallel", "decode_hot_path"}))
        tr = T.trace(pt)
        budget = R.budget_for(tr)
        f = R.check_collectives(tr, budget)
        assert f and all(x.rule == "collectives" for x in f), f
        print("CAUGHT", sorted({x.severity for x in f}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "CAUGHT ['error']" in out.stdout
