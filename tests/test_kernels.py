"""Per-kernel allclose vs the pure-jnp oracle (interpret=True on CPU):
shape/dtype sweeps for the Pallas fwd, bwd, decode kernels; the paper's
Eq. 1 kernel-fusion equivalence; sliding-chunks baseline equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels import ref
from repro.kernels.ops import decode_attention, get_pattern, swat_attention
from repro.kernels.swat_decode import decode_block_kv, swat_decode


def rand_qkv(rng, b, hq, hkv, l, d, dtype=jnp.float32):
    mk = lambda h: jnp.asarray(rng.randn(b, h, l, d), dtype)
    return mk(hq), mk(hkv), mk(hkv)


SPEC_CASES = [
    AttentionSpec(kind="swat", window=64, causal=True),
    AttentionSpec(kind="swat", window=64, causal=False),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=False),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=True),
    AttentionSpec(kind="swat", window=32, num_random=2, causal=True,
                  random_seed=7),
    AttentionSpec(kind="swat", window=32, num_global=16, num_random=1,
                  causal=False, random_seed=3),
    AttentionSpec(kind="dense", causal=True),
    AttentionSpec(kind="dense", causal=False),
    AttentionSpec(kind="swat", window=64, causal=True, softcap=30.0),
]


@pytest.mark.parametrize("spec", SPEC_CASES, ids=str)
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_forward_allclose(spec, impl, rng):
    b, hq, hkv, l, d = 2, 4, 2, 256, 64
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)
    want = ref.attention_ref(q, k, v, spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("shape", [
    (1, 1, 1, 128, 32), (2, 8, 2, 256, 64), (1, 4, 4, 320, 128),
    (3, 2, 1, 200, 64),
])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_shape_sweep(shape, impl, rng):
    b, hq, hkv, l, d = shape
    spec = AttentionSpec(kind="swat", window=48, causal=True)
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)
    want = ref.attention_ref(q, k, v, spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_dtype_sweep(dtype, atol, impl, rng):
    spec = AttentionSpec(kind="swat", window=64, num_global=8, causal=True)
    q, k, v = rand_qkv(rng, 2, 4, 2, 256, 64, dtype)
    pat = get_pattern(spec, 256, 256, 64, 64)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got.astype(jnp.float32), want,
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("spec", [
    AttentionSpec(kind="swat", window=48, causal=True),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=False),
    AttentionSpec(kind="swat", window=48, causal=True, softcap=25.0),
    AttentionSpec(kind="dense", causal=True),
], ids=str)
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_gradients_allclose(spec, impl, rng):
    b, hq, hkv, l, d = 1, 4, 2, 192, 64
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v)))

    want = jax.grad(loss(lambda q, k, v: ref.attention_ref(
        q, k, v, spec, pattern=pat)), argnums=(0, 1, 2))(q, k, v)
    got = jax.grad(loss(lambda q, k, v: swat_attention(
        q, k, v, spec, block_q=64, block_kv=64, impl=impl)),
        argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(g, w, atol=5e-5, rtol=1e-3,
                                   err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# Paper Eq. 1: the deferred-denominator fusion is EXACTLY softmax attention
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), causal=st.booleans(),
       window=st.sampled_from([16, 48]))
def test_fusion_equivalence_eq1(seed, causal, window):
    rng = np.random.RandomState(seed)
    spec = AttentionSpec(kind="swat", window=window, causal=causal)
    q, k, v = rand_qkv(rng, 1, 2, 2, 128, 32)
    three_step = ref.attention_ref(q, k, v, spec)
    fused = ref.fused_attention_ref(q, k, v, spec, stabilize=True)
    np.testing.assert_allclose(fused, three_step, atol=1e-5, rtol=1e-5)
    # the paper's literal (unstabilized) form agrees at moderate scale too
    fused_raw = ref.fused_attention_ref(q, k, v, spec, stabilize=False)
    np.testing.assert_allclose(fused_raw, three_step, atol=1e-4, rtol=1e-4)


def test_unstabilized_fusion_overflows_where_flash_does_not(rng):
    """Documents our deviation from the paper: raw exp overflows for large
    logits; the running-max version does not."""
    spec = AttentionSpec(kind="swat", window=16, causal=True)
    q, k, v = rand_qkv(rng, 1, 1, 1, 64, 32)
    q = q * 40.0  # logits ~ sqrt(32)*40^2/sqrt(32) — far beyond exp range
    raw = ref.fused_attention_ref(q, k, v, spec, stabilize=False)
    stable = ref.fused_attention_ref(q, k, v, spec, stabilize=True)
    assert not bool(jnp.isfinite(raw).all())
    assert bool(jnp.isfinite(stable).all())


def test_sliding_chunks_equals_band(rng):
    """The baseline computes the same function (only wastes FLOPs)."""
    for causal in (True, False):
        spec = AttentionSpec(kind="swat", window=32, causal=causal)
        q, k, v = rand_qkv(rng, 2, 2, 2, 256, 32)
        want = ref.attention_ref(q, k, v, spec)
        got = swat_attention(q, k, v, spec, impl="sliding_chunks")
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w,lens", [
    (128, [128, 128]), (300, [1, 299]), (64, [64, 17]), (511, [511, 200]),
])
def test_decode_kernel_allclose(w, lens, rng):
    b, hq, hkv, d = len(lens), 4, 2, 64
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    cl = jnp.asarray(lens, jnp.int32)
    got = swat_decode(q, kc, vc, cl, interpret=True)
    want = ref.decode_ref(q, kc, vc, cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_decode_ring_permutation_invariance(seed):
    """Softmax permutation invariance is what makes the ring buffer valid:
    shuffling cache rows never changes the decode output."""
    rng = np.random.RandomState(seed)
    b, h, w, d = 1, 2, 64, 32
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, h, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, h, w, d), jnp.float32)
    perm = rng.permutation(w)
    full = jnp.full((b,), w, jnp.int32)
    a = swat_decode(q, kc, vc, full, interpret=True)
    bb = swat_decode(q, kc[:, :, perm], vc[:, :, perm], full, interpret=True)
    np.testing.assert_allclose(a, bb, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("form", ["scalar", "flat", "b111"])
def test_decode_attention_cache_len_forms(form, rng):
    """decode_attention accepts every documented cache_len spelling — scalar
    (shared length), (B,), (B,1,1,1) — on BOTH impls. Regression: the pallas
    path used to jnp.reshape a scalar to (B,), which crashes for B > 1 (the
    cross-attention call site passes a full()'d (B,1,1,1))."""
    b, hq, hkv, w, d = 3, 4, 2, 64, 32
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    spec = AttentionSpec(kind="dense")
    ln = 37
    cl = {"scalar": jnp.int32(ln),
          "flat": jnp.full((b,), ln, jnp.int32),
          "b111": jnp.full((b, 1, 1, 1), ln, jnp.int32)}[form]
    want = ref.decode_ref(q, kc, vc, jnp.full((b, 1, 1, 1), ln, jnp.int32),
                          spec)
    got_ref = decode_attention(q, kc, vc, cl, spec, impl="ref")
    got_pal = decode_attention(q, kc, vc, cl, spec, impl="pallas",
                               interpret=True)
    np.testing.assert_allclose(got_ref, want, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(got_pal, want, atol=2e-5, rtol=1e-4)


def test_decode_block_kv_never_pads_rounded_capacities(rng):
    """Ring allocations from init_kv_cache are pre-rounded
    (layers.cache_allocation) so the decode hot path must tile them exactly —
    the old unconditional pad copied the WHOLE cache every token. Odd ad-hoc
    widths may still pad (the cold fallback), but must stay correct."""
    from repro.core.layers import _round_capacity
    for cap in (17, 21, 64, 100, 261, 2049):
        w = _round_capacity(cap)
        blk, pads = decode_block_kv(w)
        assert not pads and w % blk == 0, (cap, w, blk)
    # unrounded odd width: falls back to pad, output still exact
    b, hq, hkv, w, d = 2, 4, 2, 300, 32
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    cl = jnp.asarray([299, 123], jnp.int32)
    got = swat_decode(q, kc, vc, cl, interpret=True)
    want = ref.decode_ref(q, kc, vc, cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_decode_per_slot_ring_offsets(rng):
    """One batched swat_decode call serving slots at DIFFERENT ring depths
    (cold, exactly-full, wrapped, multiply-wrapped): each row's ring-laid-out
    cache must match the dense reference over that row's contiguous history
    tail — the property the continuous-batching engine relies on."""
    b, hq, hkv, w, d = 4, 4, 2, 32, 16
    lens = [5, 32, 47, 90]
    kc_ring = np.zeros((b, hkv, w, d), np.float32)
    vc_ring = np.zeros((b, hkv, w, d), np.float32)
    kc_lin = np.zeros((b, hkv, w, d), np.float32)
    vc_lin = np.zeros((b, hkv, w, d), np.float32)
    for i, ln in enumerate(lens):
        hk = rng.randn(hkv, ln, d).astype(np.float32)
        hv = rng.randn(hkv, ln, d).astype(np.float32)
        start = max(0, ln - w)
        for t in range(start, ln):          # FIFO: token t lives at t % w
            kc_ring[i, :, t % w] = hk[:, t]
            vc_ring[i, :, t % w] = hv[:, t]
        kc_lin[i, :, :ln - start] = hk[:, start:]
        vc_lin[i, :, :ln - start] = hv[:, start:]
    cl = jnp.asarray([min(ln, w) for ln in lens], jnp.int32)
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    got = swat_decode(q, jnp.asarray(kc_ring), jnp.asarray(vc_ring), cl,
                      interpret=True)
    want = ref.decode_ref(q, jnp.asarray(kc_lin), jnp.asarray(vc_lin),
                          cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)
