"""Per-kernel allclose vs the pure-jnp oracle (interpret=True on CPU):
shape/dtype sweeps for the Pallas fwd, bwd, decode kernels; the paper's
Eq. 1 kernel-fusion equivalence; sliding-chunks baseline equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic seeded fallback (tier-1)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import patterns
from repro.core.types import AttentionSpec
from repro.kernels import ref
from repro.kernels.ops import decode_attention, get_pattern, swat_attention
from repro.kernels.swat_decode import decode_block_kv, swat_decode


def rand_qkv(rng, b, hq, hkv, l, d, dtype=jnp.float32):
    mk = lambda h: jnp.asarray(rng.randn(b, h, l, d), dtype)
    return mk(hq), mk(hkv), mk(hkv)


SPEC_CASES = [
    AttentionSpec(kind="swat", window=64, causal=True),
    AttentionSpec(kind="swat", window=64, causal=False),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=False),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=True),
    AttentionSpec(kind="swat", window=32, num_random=2, causal=True,
                  random_seed=7),
    AttentionSpec(kind="swat", window=32, num_global=16, num_random=1,
                  causal=False, random_seed=3),
    AttentionSpec(kind="dense", causal=True),
    AttentionSpec(kind="dense", causal=False),
    AttentionSpec(kind="swat", window=64, causal=True, softcap=30.0),
]


@pytest.mark.parametrize("spec", SPEC_CASES, ids=str)
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_forward_allclose(spec, impl, rng):
    b, hq, hkv, l, d = 2, 4, 2, 256, 64
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)
    want = ref.attention_ref(q, k, v, spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("shape", [
    (1, 1, 1, 128, 32), (2, 8, 2, 256, 64), (1, 4, 4, 320, 128),
    (3, 2, 1, 200, 64),
])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_shape_sweep(shape, impl, rng):
    b, hq, hkv, l, d = shape
    spec = AttentionSpec(kind="swat", window=48, causal=True)
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)
    want = ref.attention_ref(q, k, v, spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_dtype_sweep(dtype, atol, impl, rng):
    spec = AttentionSpec(kind="swat", window=64, num_global=8, causal=True)
    q, k, v = rand_qkv(rng, 2, 4, 2, 256, 64, dtype)
    pat = get_pattern(spec, 256, 256, 64, 64)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), spec, pattern=pat)
    got = swat_attention(q, k, v, spec, block_q=64, block_kv=64, impl=impl)
    np.testing.assert_allclose(got.astype(jnp.float32), want,
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("spec", [
    AttentionSpec(kind="swat", window=48, causal=True),
    AttentionSpec(kind="swat", window=32, num_global=16, causal=False),
    AttentionSpec(kind="swat", window=48, causal=True, softcap=25.0),
    AttentionSpec(kind="dense", causal=True),
], ids=str)
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_gradients_allclose(spec, impl, rng):
    b, hq, hkv, l, d = 1, 4, 2, 192, 64
    q, k, v = rand_qkv(rng, b, hq, hkv, l, d)
    pat = get_pattern(spec, l, l, 64, 64)

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v)))

    want = jax.grad(loss(lambda q, k, v: ref.attention_ref(
        q, k, v, spec, pattern=pat)), argnums=(0, 1, 2))(q, k, v)
    got = jax.grad(loss(lambda q, k, v: swat_attention(
        q, k, v, spec, block_q=64, block_kv=64, impl=impl)),
        argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(g, w, atol=5e-5, rtol=1e-3,
                                   err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# Paper Eq. 1: the deferred-denominator fusion is EXACTLY softmax attention
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), causal=st.booleans(),
       window=st.sampled_from([16, 48]))
def test_fusion_equivalence_eq1(seed, causal, window):
    rng = np.random.RandomState(seed)
    spec = AttentionSpec(kind="swat", window=window, causal=causal)
    q, k, v = rand_qkv(rng, 1, 2, 2, 128, 32)
    three_step = ref.attention_ref(q, k, v, spec)
    fused = ref.fused_attention_ref(q, k, v, spec, stabilize=True)
    np.testing.assert_allclose(fused, three_step, atol=1e-5, rtol=1e-5)
    # the paper's literal (unstabilized) form agrees at moderate scale too
    fused_raw = ref.fused_attention_ref(q, k, v, spec, stabilize=False)
    np.testing.assert_allclose(fused_raw, three_step, atol=1e-4, rtol=1e-4)


def test_unstabilized_fusion_overflows_where_flash_does_not(rng):
    """Documents our deviation from the paper: raw exp overflows for large
    logits; the running-max version does not."""
    spec = AttentionSpec(kind="swat", window=16, causal=True)
    q, k, v = rand_qkv(rng, 1, 1, 1, 64, 32)
    q = q * 40.0  # logits ~ sqrt(32)*40^2/sqrt(32) — far beyond exp range
    raw = ref.fused_attention_ref(q, k, v, spec, stabilize=False)
    stable = ref.fused_attention_ref(q, k, v, spec, stabilize=True)
    assert not bool(jnp.isfinite(raw).all())
    assert bool(jnp.isfinite(stable).all())


def test_sliding_chunks_equals_band(rng):
    """The baseline computes the same function (only wastes FLOPs)."""
    for causal in (True, False):
        spec = AttentionSpec(kind="swat", window=32, causal=causal)
        q, k, v = rand_qkv(rng, 2, 2, 2, 256, 32)
        want = ref.attention_ref(q, k, v, spec)
        got = swat_attention(q, k, v, spec, impl="sliding_chunks")
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w,lens", [
    (128, [128, 128]), (300, [1, 299]), (64, [64, 17]), (511, [511, 200]),
])
def test_decode_kernel_allclose(w, lens, rng):
    b, hq, hkv, d = len(lens), 4, 2, 64
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    cl = jnp.asarray(lens, jnp.int32)
    got = swat_decode(q, kc, vc, cl, interpret=True)
    want = ref.decode_ref(q, kc, vc, cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_decode_ring_permutation_invariance(seed):
    """Softmax permutation invariance is what makes the ring buffer valid:
    shuffling cache rows never changes the decode output."""
    rng = np.random.RandomState(seed)
    b, h, w, d = 1, 2, 64, 32
    q = jnp.asarray(rng.randn(b, h, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, h, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, h, w, d), jnp.float32)
    perm = rng.permutation(w)
    full = jnp.full((b,), w, jnp.int32)
    a = swat_decode(q, kc, vc, full, interpret=True)
    bb = swat_decode(q, kc[:, :, perm], vc[:, :, perm], full, interpret=True)
    np.testing.assert_allclose(a, bb, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("form", ["scalar", "flat", "b111"])
def test_decode_attention_cache_len_forms(form, rng):
    """decode_attention accepts every documented cache_len spelling — scalar
    (shared length), (B,), (B,1,1,1) — on BOTH impls. Regression: the pallas
    path used to jnp.reshape a scalar to (B,), which crashes for B > 1 (the
    cross-attention call site passes a full()'d (B,1,1,1))."""
    b, hq, hkv, w, d = 3, 4, 2, 64, 32
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    spec = AttentionSpec(kind="dense")
    ln = 37
    cl = {"scalar": jnp.int32(ln),
          "flat": jnp.full((b,), ln, jnp.int32),
          "b111": jnp.full((b, 1, 1, 1), ln, jnp.int32)}[form]
    want = ref.decode_ref(q, kc, vc, jnp.full((b, 1, 1, 1), ln, jnp.int32),
                          spec)
    got_ref = decode_attention(q, kc, vc, cl, spec, impl="ref")
    got_pal = decode_attention(q, kc, vc, cl, spec, impl="pallas",
                               interpret=True)
    np.testing.assert_allclose(got_ref, want, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(got_pal, want, atol=2e-5, rtol=1e-4)


def test_decode_block_kv_never_pads_rounded_capacities(rng):
    """Ring allocations from init_kv_cache are pre-rounded
    (layers.cache_allocation) so the decode hot path must tile them exactly —
    the old unconditional pad copied the WHOLE cache every token. Odd ad-hoc
    widths may still pad (the cold fallback), but must stay correct."""
    from repro.core.layers import _round_capacity
    for cap in (17, 21, 64, 100, 261, 2049):
        w = _round_capacity(cap)
        blk, pads = decode_block_kv(w)
        assert not pads and w % blk == 0, (cap, w, blk)
    # unrounded odd width: falls back to pad, output still exact
    b, hq, hkv, w, d = 2, 4, 2, 300, 32
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    cl = jnp.asarray([299, 123], jnp.int32)
    got = swat_decode(q, kc, vc, cl, interpret=True)
    want = ref.decode_ref(q, kc, vc, cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def _fifo_ring_caches(rng, lens, hkv, cap, alloc, d, num_global=0,
                      dtype=np.float32):
    """Build per-slot ring caches by simulating sequential FIFO insertion
    (pinned globals below num_global, ring above), plus the linear "last
    window" layout for dense-reference checks. Returns (k_ring, v_ring)."""
    b = len(lens)
    kc = np.zeros((b, hkv, alloc, d), dtype)
    vc = np.zeros((b, hkv, alloc, d), dtype)
    ring = cap - num_global
    for i, ln in enumerate(lens):
        hk = rng.randn(hkv, max(ln, 1), d).astype(dtype)
        hv = rng.randn(hkv, max(ln, 1), d).astype(dtype)
        for t in range(ln):
            slot = t if t < num_global else (num_global
                                             + (t - num_global) % ring)
            kc[i, :, slot] = hk[:, t]
            vc[i, :, slot] = hv[:, t]
    return kc, vc


@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("t", [1, 4])
@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_decode_fused_parity_sweep(group, t, dtype, atol, rng):
    """The ISSUE-3 acceptance sweep: the fused pallas kernel (GQA-packed
    query tile, in-kernel ring insert) matches the jnp oracle across
    group in {1,4,8}, T in {1,4}, bf16/fp32, and mixed per-slot depths —
    cold slots, partially filled, freshly wrapped, multiply wrapped. Cache
    updates must be BITWISE identical (broken aliasing shows up here)."""
    spec = AttentionSpec(kind="swat", window=12, num_global=4, causal=True)
    hkv, d = 2, 32
    hq = group * hkv
    cap = spec.window + 1 + (t - 1) + spec.num_global  # lookahead ring
    from repro.core.layers import _round_capacity
    alloc = _round_capacity(cap)                       # tile-rounded tail
    lens = [0, 3, cap - 1, cap, 4 * cap + 7]           # per-slot depths
    b = len(lens)
    np_dtype = np.float32
    kc, vc = _fifo_ring_caches(rng, lens, hkv, cap, alloc, d,
                               num_global=spec.num_global, dtype=np_dtype)
    kc, vc = jnp.asarray(kc, dtype), jnp.asarray(vc, dtype)
    q = jnp.asarray(rng.randn(b, hq, t, d), dtype)
    nk = jnp.asarray(rng.randn(b, hkv, t, d), dtype)
    nv = jnp.asarray(rng.randn(b, hkv, t, d), dtype)
    pos = jnp.asarray(lens, jnp.int32)
    nn = jnp.asarray([t, t, max(1, t - 1), t, t], jnp.int32)  # ragged
    o_ref, kr, vr = decode_attention(q, kc, vc, None, spec, impl="ref",
                                     new_kv=(nk, nv), num_new=nn, pos=pos,
                                     ring_cap=cap)
    o_pal, kp, vp = decode_attention(q, kc, vc, None, spec, impl="pallas",
                                     new_kv=(nk, nv), num_new=nn, pos=pos,
                                     ring_cap=cap, interpret=True)
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(kp))
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(vp))
    for i in range(b):
        real = int(nn[i])    # rows past num_new are garbage by contract
        np.testing.assert_allclose(
            np.asarray(o_pal[i, :, :real], np.float32),
            np.asarray(o_ref[i, :, :real], np.float32),
            atol=atol, rtol=1e-2, err_msg=f"slot {i}")


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_decode_multi_token_equals_sequential(impl, rng):
    """A T=4 fused decode call == 4 sequential T=1 fused calls, outputs and
    final caches alike — the property speculative-decode verification
    stands on. Needs the lookahead ring (T-1 extra rows): without it the
    step's own inserts would evict tokens still inside early queries'
    windows."""
    spec = AttentionSpec(kind="swat", window=10, num_global=2, causal=True)
    hkv, group, t, d = 2, 3, 4, 16
    hq = hkv * group
    cap = spec.window + 1 + (t - 1) + spec.num_global
    lens = [0, 5, 3 * cap + 2]
    b = len(lens)
    kc, vc = _fifo_ring_caches(rng, lens, hkv, cap, cap, d,
                               num_global=spec.num_global)
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    q = jnp.asarray(rng.randn(b, hq, t, d), jnp.float32)
    nk = jnp.asarray(rng.randn(b, hkv, t, d), jnp.float32)
    nv = jnp.asarray(rng.randn(b, hkv, t, d), jnp.float32)
    pos = jnp.asarray(lens, jnp.int32)
    out, kA, vA = decode_attention(q, kc, vc, None, spec, impl=impl,
                                   new_kv=(nk, nv), pos=pos, ring_cap=cap,
                                   interpret=True)
    outs = []
    for j in range(t):
        o1, kc, vc = decode_attention(
            q[:, :, j:j + 1], kc, vc, None, spec, impl=impl,
            new_kv=(nk[:, :, j:j + 1], nv[:, :, j:j + 1]),
            pos=pos + j, ring_cap=cap, interpret=True)
        outs.append(o1)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.concatenate(outs, 2)),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(kA), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(vA), np.asarray(vc))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_decode_window_masked_on_wide_cache(impl, rng):
    """Regression (ISSUE 3): a cache allocated wider than the ring capacity
    (dense-capped / lookahead allocations) used to attend the ENTIRE valid
    prefix — spec.window was silently dropped. Both impls must mask by
    per-slot ring distance: only the last window+1 tokens (plus pinned
    globals) are visible."""
    spec = AttentionSpec(kind="swat", window=8, causal=True)
    b, hq, hkv, W, L, d = 1, 4, 2, 64, 40, 16
    kc = jnp.asarray(rng.randn(b, hkv, W, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, W, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    nk = jnp.asarray(rng.randn(b, hkv, 1, d), jnp.float32)
    nv = jnp.asarray(rng.randn(b, hkv, 1, d), jnp.float32)
    pos = jnp.asarray([L], jnp.int32)
    got, _, _ = decode_attention(q, kc, vc, None, spec, impl=impl,
                                 new_kv=(nk, nv), pos=pos, ring_cap=W,
                                 interpret=True)
    # oracle: dense attention over ONLY the in-window tail (linear layout:
    # token i at slot i; query at L sees [L-8, L])
    kw = jnp.concatenate([kc[:, :, L - 8:L], nk], axis=2)
    vw = jnp.concatenate([vc[:, :, L - 8:L], nv], axis=2)
    want = ref.decode_ref(q, kw, vw, jnp.full((b, 1, 1, 1), 9, jnp.int32),
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)
    # and the buggy behavior is measurably different: attending the whole
    # prefix gives a different answer
    wide = ref.decode_ref(q, kc.at[:, :, L].set(nk[:, :, 0]),
                          vc.at[:, :, L].set(nv[:, :, 0]),
                          jnp.full((b, 1, 1, 1), L + 1, jnp.int32),
                          AttentionSpec(kind="dense"))
    assert not np.allclose(np.asarray(got), np.asarray(wide), atol=1e-3)


def test_decode_fused_equals_unfused_bitwise(rng):
    """The fused ref path (insert inside decode_attention) must be
    OP-FOR-OP the PR-2 unfused path (layers._dyn_update scatter, then
    prefix-masked attention): bitwise-equal caches AND outputs at T=1 on a
    standard ring. This is what keeps serving tokens byte-stable across
    the refactor (the slot-parallel mesh parity test rides on it)."""
    from repro.core.layers import _dyn_update
    spec = AttentionSpec(kind="swat", window=12, num_global=4, causal=True)
    hkv, group, d = 2, 2, 16
    hq = hkv * group
    cap = spec.window + 1 + spec.num_global
    lens = [0, 5, cap, 3 * cap + 2]
    b = len(lens)
    kc, vc = _fifo_ring_caches(rng, lens, hkv, cap, cap, d,
                               num_global=spec.num_global)
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    nk = jnp.asarray(rng.randn(b, hkv, 1, d), jnp.float32)
    nv = jnp.asarray(rng.randn(b, hkv, 1, d), jnp.float32)
    step = jnp.asarray(lens, jnp.int32)
    fused, kf, vf = decode_attention(q, kc, vc, None, spec, impl="ref",
                                     new_kv=(nk, nv), pos=step,
                                     ring_cap=cap)
    g, ring = spec.num_global, cap - spec.num_global
    slot = jnp.where(step < g, step, g + (step - g) % ring)
    ku = _dyn_update(kc, nk, slot)
    vu = _dyn_update(vc, nv, slot)
    unfused = decode_attention(q, ku, vu,
                               jnp.minimum(step + 1, cap), spec, impl="ref")
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(ku))
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vu))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def test_decode_pad_fallback_warns_once(rng, caplog):
    """The pad-and-copy fallback (cache width with no sublane-aligned
    divisor) must log a one-time warning naming the offending W — the
    silent full-cache copy per token is exactly what went unnoticed before
    the pre-rounded allocations."""
    import logging
    from repro.kernels import swat_decode as sd
    w = 37  # no divisor >= 16 shared with 128
    assert decode_block_kv(w)[1]
    sd._PAD_WARNED.discard(w)
    b, hq, hkv, d = 1, 2, 1, 32
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, w, d), jnp.float32)
    cl = jnp.full((b,), w, jnp.int32)
    with caplog.at_level(logging.WARNING, logger=sd.logger.name):
        swat_decode(q, kc, vc, cl, interpret=True)
        swat_decode(q, kc, vc, cl, interpret=True)
    hits = [r for r in caplog.records if "W=37" in r.getMessage()]
    assert len(hits) == 1, [r.getMessage() for r in caplog.records]


def test_decode_per_slot_ring_offsets(rng):
    """One batched swat_decode call serving slots at DIFFERENT ring depths
    (cold, exactly-full, wrapped, multiply-wrapped): each row's ring-laid-out
    cache must match the dense reference over that row's contiguous history
    tail — the property the continuous-batching engine relies on."""
    b, hq, hkv, w, d = 4, 4, 2, 32, 16
    lens = [5, 32, 47, 90]
    kc_ring = np.zeros((b, hkv, w, d), np.float32)
    vc_ring = np.zeros((b, hkv, w, d), np.float32)
    kc_lin = np.zeros((b, hkv, w, d), np.float32)
    vc_lin = np.zeros((b, hkv, w, d), np.float32)
    for i, ln in enumerate(lens):
        hk = rng.randn(hkv, ln, d).astype(np.float32)
        hv = rng.randn(hkv, ln, d).astype(np.float32)
        start = max(0, ln - w)
        for t in range(start, ln):          # FIFO: token t lives at t % w
            kc_ring[i, :, t % w] = hk[:, t]
            vc_ring[i, :, t % w] = hv[:, t]
        kc_lin[i, :, :ln - start] = hk[:, start:]
        vc_lin[i, :, :ln - start] = hv[:, start:]
    cl = jnp.asarray([min(ln, w) for ln in lens], jnp.int32)
    q = jnp.asarray(rng.randn(b, hq, 1, d), jnp.float32)
    got = swat_decode(q, jnp.asarray(kc_ring), jnp.asarray(vc_ring), cl,
                      interpret=True)
    want = ref.decode_ref(q, jnp.asarray(kc_lin), jnp.asarray(vc_lin),
                          cl[:, None, None, None],
                          AttentionSpec(kind="dense"))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)
