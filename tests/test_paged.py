"""Paged ring KV: block tables, prefix sharing, per-layer windows.

The paged cache contract (serving/paged.py + layers.init_paged_kv_cache)
promises paged decode is BITWISE the contiguous ring — PAGE_SIZE divides
every sparse allocation by construction, so the gather-view the kernel
sees has exactly the contiguous physical width. Everything here pins that:

  * paged engine == contiguous engine token-for-token, on the ref and
    pallas decode impls, sequential and speculative (k=2), and under
    chaos cache-poison quarantine,
  * prefix sharing: a batch with a common system prompt prefills the
    prefix ONCE (prefill_tokens_computed < 0.5x the no-sharing engine),
    block-shares the untouched prefix pages, copy-on-writes at the
    divergence point — and still emits identical tokens,
  * per-layer `window_schedule` (gemma2-style local/global alternation)
    allocates DISTINCT cache capacities per layer and decodes unchanged,
  * bounded retry: a request whose slot died with the donated caches is
    readmitted through the normal queue up to `max_retries` times,
  * host-side invariants (refcounts never negative, the free list never
    double-frees, trie match == longest common prefix) swept generatively
    under hypothesis or the deterministic fallback shim.
"""
import collections
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_smoke_config, with_swat
from repro.core import model as Mod
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.paged import (BlockAllocator, PagedManager, RadixTrie,
                                 batch_lcp)
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def swat_setup():
    cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16, num_global=4)
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("llama3p2_1b")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, reqs, **kw):
    eng = ServingEngine(cfg, params, **kw)
    res = eng.run(reqs)
    return eng, {r.rid: r for r in res}


def _reqs(cfg, seed=0, n=4, budget=8, temps=None):
    rng = np.random.RandomState(seed)
    lens = rng.randint(5, 30, n)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (l,)).astype(
                        np.int32),
                    max_new_tokens=budget,
                    temperature=0.0 if temps is None else temps[i])
            for i, l in enumerate(lens)]


# ------------------------------------------------------- token identity ----

@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("which", ["swat", "dense"])
def test_paged_token_identical(impl, which, swat_setup, dense_setup):
    """Paged decode == contiguous decode, bitwise, with slot eviction and
    reuse (more requests than slots) and a sampled row in the mix."""
    cfg, params = swat_setup if which == "swat" else dense_setup
    temps = [0.0, 0.0, 0.9, 0.0]
    kw = dict(batch_slots=2, max_len=256, decode_impl=impl)
    _, a = _run(cfg, params, _reqs(cfg, temps=temps), **kw)
    _, b = _run(cfg, params, _reqs(cfg, temps=temps), kv_layout="paged",
                **kw)
    for rid in a:
        assert a[rid].tokens == b[rid].tokens, (impl, which, rid)


def test_paged_speculative_identical(swat_setup):
    cfg, params = swat_setup
    kw = dict(batch_slots=2, max_len=256, speculative=2)
    _, a = _run(cfg, params, _reqs(cfg, seed=3, n=3, budget=10), **kw)
    eng, b = _run(cfg, params, _reqs(cfg, seed=3, n=3, budget=10),
                  kv_layout="paged", **kw)
    for rid in a:
        assert a[rid].tokens == b[rid].tokens
    assert eng.stats["spec_steps"] > 0


def test_paged_chaos_quarantine(swat_setup):
    """Cache poison on a paged slot quarantines only that slot; the
    poison forces the slot's blocks private first so refcount-shared
    pages can't leak NaN into other slots."""
    cfg, params = swat_setup
    plan = FaultPlan(poison_cache=((1, 3),))
    kw = dict(batch_slots=3, max_len=256, kv_layout="paged")
    _, clean = _run(cfg, params, _reqs(cfg, seed=5, n=3, budget=10), **kw)
    _, hurt = _run(cfg, params, _reqs(cfg, seed=5, n=3, budget=10),
                   faults=plan, **kw)
    assert hurt[1].status == "poisoned"
    assert hurt[1].tokens == clean[1].tokens[:len(hurt[1].tokens)]
    for rid in (0, 2):
        assert hurt[rid].status == "ok"
        assert hurt[rid].tokens == clean[rid].tokens


# ------------------------------------------------------- prefix sharing ----

@pytest.mark.parametrize("which", ["swat", "dense"])
def test_prefix_sharing_identical_and_cheaper(which, swat_setup,
                                              dense_setup):
    """>= 8 requests behind one system prompt: sharing prefills the prefix
    once (< 0.5x the tokens), dedups prefix blocks in the pool, and still
    produces identical tokens — COW covers the post-prefix divergence."""
    cfg, params = swat_setup if which == "swat" else dense_setup
    rng = np.random.RandomState(7)
    sys_p = rng.randint(0, cfg.vocab_size, (96,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_p, rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)])
        for _ in range(8)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
    kw = dict(batch_slots=8, max_len=256, kv_layout="paged",
              prefill_chunk=32)
    off, r_off = _run(cfg, params, reqs(), **kw)
    on = ServingEngine(cfg, params, share_prefix=True, **kw)
    # admit first so pool occupancy is observable before slots retire
    pend = collections.deque(reqs())
    on._run_t0 = 0.0
    on._admit(pend)
    shared_blocks = on.paged_stats()["blocks_in_use"]
    on._run_t0 = None
    r_on = {r.rid: r for r in on.run(list(pend)) + on.take_completed()}
    for rid in r_off:
        assert r_off[rid].tokens == r_on[rid].tokens, (which, rid)
    assert on.stats["prefill_prefix_shared"] >= 1
    ratio = (on.stats["prefill_tokens_computed"]
             / off.stats["prefill_tokens_computed"])
    assert ratio < 0.5, ratio
    if which == "dense":
        # dense layers map positions to pages 1:1, so the 96-token prefix
        # must dedup: leader pages + one divergence page per follower,
        # far below 8 private full allocations
        total = on.paged_stats()["blocks_total"]
        assert shared_blocks < total // 2, (shared_blocks, total)


def test_scheduler_plans_prefix_len():
    sched = Scheduler(max_prefill_tokens=8192, pad_to=16)
    shared = np.arange(40, dtype=np.int32)
    pend = collections.deque([
        Request(rid=0, prompt=np.concatenate([shared, [100, 101]])),
        Request(rid=1, prompt=np.concatenate([shared, [200, 201, 202]])),
        Request(rid=2, prompt=np.concatenate([shared, [300]])),
    ])
    plan = sched.plan(pend, 3)
    assert plan.prefix_len == 40
    pend = collections.deque([
        Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32)),
        Request(rid=1, prompt=np.asarray([4, 5, 6], np.int32)),
    ])
    assert sched.plan(pend, 2).prefix_len == 0
    pend = collections.deque([Request(rid=0, prompt=shared)])
    assert sched.plan(pend, 1).prefix_len == 0  # single row: nothing shared


# --------------------------------------------------- per-layer windows -----

def test_gemma2_window_schedule_distinct_capacities():
    """gemma2-style local/global alternation with per-layer windows: the
    paged layout allocates DISTINCT per-layer cache capacities and decode
    is unchanged between layouts."""
    cfg = get_smoke_config("gemma2_2b")
    assert cfg.layer_pattern == ("local_attn", "attn")
    sched = tuple(8 if k == "local_attn" else 24 for k in cfg.layer_pattern)
    cfg2 = dataclasses.replace(cfg, window_schedule=sched)
    layout = Mod.paged_layout(cfg2, 256)
    caps = [layout[i]["cap"] for i in sorted(layout)]
    # local layer: w=8 -> 9 rows; global layer: dense overridden to w=24
    # -> 25 rows (not the dense 256) — genuinely per-layer capacities
    assert caps == [9, 25], caps
    base = Mod.paged_layout(cfg, 256)
    assert [base[i]["cap"] for i in sorted(base)] == [17, 256]
    params = Mod.init_model(jax.random.PRNGKey(0), cfg2)
    kw = dict(batch_slots=2, max_len=256)
    _, a = _run(cfg2, params, _reqs(cfg2, seed=9, n=2, budget=6), **kw)
    _, b = _run(cfg2, params, _reqs(cfg2, seed=9, n=2, budget=6),
                kv_layout="paged", **kw)
    for rid in a:
        assert a[rid].tokens == b[rid].tokens


def test_window_schedule_validation():
    cfg = get_smoke_config("gemma2_2b")
    with pytest.raises(AssertionError):
        dataclasses.replace(cfg, window_schedule=(8,))      # wrong length
    with pytest.raises(AssertionError):
        dataclasses.replace(cfg, window_schedule=(0, None))  # w must be > 0
    ok = dataclasses.replace(cfg, window_schedule=(None, 32))
    assert ok.window_schedule == (None, 32)


# ------------------------------------------------------- bounded retry -----

@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_retry_readmission_after_cache_loss(layout, swat_setup):
    """A mid-execution kernel failure that consumed the donated caches
    finalizes slots as 'failed' — unless the request carries max_retries,
    in which case it is readmitted through the normal queue and re-served
    from the prompt, with the retry count surfaced on the Result."""
    cfg, params = swat_setup
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)
               for _ in range(2)]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=256,
                        kv_layout=layout)
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=8,
                    max_retries=1),
            Request(rid=1, prompt=prompts[1], max_new_tokens=8)]
    pend = collections.deque(reqs)
    eng._run_t0 = 0.0
    eng._admit(pend)
    eng._decode_block(2)
    for leaf in jax.tree.leaves(eng.caches):
        leaf.delete()          # simulate consumed donation
    with pytest.warns(RuntimeWarning):
        done = eng._kernel_fallback(RuntimeError("boom"), 2)
    eng._run_t0 = None
    rest = eng.run([])
    res = {r.rid: r for r in done + rest + eng.take_completed()}
    assert res[1].status == "failed" and res[1].retries == 0
    assert res[0].status == "ok" and res[0].retries == 1
    assert eng.stats["readmitted"] == 1
    clean = ServingEngine(cfg, params, batch_slots=2, max_len=256).run(
        [Request(rid=0, prompt=prompts[0], max_new_tokens=8)])
    assert res[0].tokens == clean[0].tokens


def test_retry_zero_still_fails(swat_setup):
    cfg, params = swat_setup
    rng = np.random.RandomState(12)
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=256,
                        kv_layout="paged")
    req = Request(rid=0, prompt=rng.randint(0, cfg.vocab_size, (10,)
                                            ).astype(np.int32),
                  max_new_tokens=8)
    pend = collections.deque([req])
    eng._run_t0 = 0.0
    eng._admit(pend)
    for leaf in jax.tree.leaves(eng.caches):
        leaf.delete()
    with pytest.warns(RuntimeWarning):
        done = eng._kernel_fallback(RuntimeError("boom"), 1)
    eng._run_t0 = None
    assert done[0].status == "failed" and done[0].retries == 0


# --------------------------------------------------- host-side invariants --

@settings(max_examples=30)
@given(seed=st.integers(min_value=0, max_value=10_000),
       num_blocks=st.integers(min_value=4, max_value=24))
def test_allocator_invariants(seed, num_blocks):
    """Refcounts never go negative, the free list never double-holds an
    id, reserved ids are never handed out — against a reference model."""
    rng = np.random.RandomState(seed)
    reserved = set(rng.choice(num_blocks, size=rng.randint(0, 3),
                              replace=False).tolist())
    alc = BlockAllocator(num_blocks, reserved=reserved)
    ref: dict = {}
    live: list = []
    for _ in range(200):
        op = rng.randint(0, 3)
        if op == 0 and alc.free_count:
            bid = alc.alloc()
            assert bid not in reserved
            assert ref.get(bid, 0) == 0, "alloc returned a referenced block"
            ref[bid] = 1
            live.append(bid)
        elif op == 1 and live:
            bid = live[rng.randint(len(live))]
            alc.retain(bid)
            ref[bid] += 1
            live.append(bid)
        elif op == 2 and live:
            bid = live.pop(rng.randint(len(live)))
            alc.release(bid)
            ref[bid] -= 1
            assert ref[bid] >= 0
        for bid in set(live):
            assert alc.refcount(bid) == ref[bid]
    assert alc.allocated == sum(1 for v in ref.values() if v > 0)
    # double-free of anything already at refcount 0 must raise
    dead = [b for b, v in ref.items() if v == 0]
    if dead:
        with pytest.raises(RuntimeError):
            alc.release(dead[0])
    # retain of a never-allocated block must raise
    with pytest.raises(RuntimeError):
        BlockAllocator(4).retain(0)


@settings(max_examples=30)
@given(seed=st.integers(min_value=0, max_value=10_000),
       nseq=st.integers(min_value=1, max_value=8),
       alpha=st.integers(min_value=2, max_value=5))
def test_radix_trie_matches_bruteforce_lcp(seed, nseq, alpha):
    """Trie longest_prefix == max pairwise LCP against every inserted
    sequence, on a small alphabet (forces edge splits)."""
    rng = np.random.RandomState(seed)
    seqs = [rng.randint(0, alpha, rng.randint(1, 20)).tolist()
            for _ in range(nseq)]
    trie = RadixTrie()
    for s in seqs:
        trie.insert(s)
    assert len(trie) == nseq

    def lcp(a, b):
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n
    for _ in range(10):
        q = rng.randint(0, alpha, rng.randint(0, 25)).tolist()
        want = max((lcp(q, s) for s in seqs), default=0)
        assert trie.longest_prefix(q) == want, (q, seqs)
    # batch_lcp == brute force common prefix of ALL rows
    want_all = min((lcp(seqs[0], s) for s in seqs[1:]),
                   default=len(seqs[0])) if nseq > 1 else 0
    assert batch_lcp(seqs) == (want_all if nseq > 1 else 0)


@settings(max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_paged_manager_admit_free_cow_cycle(seed):
    """Random admit/free/cow cycles on a shared-mode manager: parked
    tables always point at scratch, occupied tables never reference a
    freed block, COW leaves every written block exclusively owned, and
    the shared-prefix retains keep the allocator balanced (reset drains
    to zero without a double-free)."""
    rng = np.random.RandomState(seed)
    layout = {0: {"page": 4, "nb": 6, "cap": 24, "g": 2, "ring": 22},
              2: {"page": 4, "nb": 3, "cap": 12, "g": 0, "ring": 12}}
    slots = 4
    pm = PagedManager(layout, slots, mode="shared")
    pos = np.zeros(slots, np.int64)
    for _ in range(60):
        op = rng.randint(0, 3)
        parked = [s for s in range(slots) if pm.parked[s]]
        busy = [s for s in range(slots) if not pm.parked[s]]
        if op == 0 and parked:
            take = sorted(rng.choice(parked,
                                     rng.randint(1, len(parked) + 1),
                                     replace=False).tolist())
            l_pad = int(rng.randint(8, 30))
            prefix = int(rng.randint(0, l_pad)) if len(take) >= 2 else 0
            pm.admit(take, [l_pad] * len(take), prefix_len=prefix)
            pos[take] = l_pad
        elif op == 1 and busy:
            s = busy[rng.randint(len(busy))]
            pm.free(s)
            pm.free(s)                      # idempotent, never double-free
        elif op == 2 and busy:
            span = int(rng.randint(1, 6))
            moves = pm.cow_moves({s: int(pos[s]) for s in busy}, span)
            for i, geo in layout.items():
                page, g, ring = geo["page"], geo["g"], geo["ring"]
                for s in busy:
                    p = np.arange(pos[s], pos[s] + span)
                    rows = np.where(p < g, p, g + (p - g) % ring)
                    for b in np.unique(rows // page):
                        bid = int(pm.tables[i][s][b])
                        assert pm.alloc[i].refcount(bid) == 1, \
                            "COW left a written block shared"
                for src, dst in moves[i]:
                    assert pm.alloc[i].refcount(dst) >= 1
            pos[busy] += span
        for i in pm.layout:
            for s in range(slots):
                if pm.parked[s]:
                    assert (pm.tables[i][s] == pm.scratch_id(i, s)).all()
                else:
                    for bid in pm.tables[i][s]:
                        assert pm.alloc[i].refcount(int(bid)) >= 1
    for s in range(slots):
        pm.free(s)
    assert pm.blocks_in_use() == 0


# ------------------------------------------------------- sharded paged -----

@pytest.mark.slow
def test_paged_slot_parallel_mesh():
    """4-device slot-parallel mesh: the paged engine (local-id pool, one-hot
    gather) is token-identical to the single-device contiguous engine, and
    the pool/table leaves actually shard over the slot axis."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    code = """
        import jax
        import numpy as np
        from repro.configs import get_smoke_config, with_swat
        from repro.core import model as Mod
        from repro.launch import mesh as mesh_lib
        from repro.serving.engine import Request, ServingEngine

        assert jax.device_count() == 4, jax.devices()
        cfg = with_swat(get_smoke_config("llama3p2_1b"), window=16,
                        num_global=4)
        params = Mod.init_model(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(3)
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in (12, 30, 7, 18, 25, 10)]
        temps = [0.0, 1.5, 0.0, 2.5, 1.0, 0.0]
        budgets = [6, 9, 4, 7, 5, 8]

        def reqs():
            return [Request(rid=i, prompt=prompts[i],
                            max_new_tokens=budgets[i],
                            temperature=temps[i]) for i in range(6)]

        def run(mesh, **kw):
            eng = ServingEngine(cfg, params, batch_slots=4, max_len=128,
                                scan_steps=4, seed=11, mesh=mesh, **kw)
            return eng, {r.rid: r.tokens for r in eng.run(reqs())}

        _, base = run(None)
        eng, paged = run(mesh_lib.make_debug_mesh(4, 1), kv_layout="paged")
        assert paged == base, (paged, base)

        # the pool must actually shard: slot dim over 'data' on pk/pv and
        # on the block table (replication is the silent failure mode)
        seen = {"pk": 0, "table": 0}
        def visit(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name in seen:
                spec = tuple(leaf.sharding.spec)
                axes = [a for e in spec if e is not None
                        for a in ((e,) if isinstance(e, str) else e)]
                assert "data" in axes, (name, spec)
                assert spec[1] == "data", (name, spec)   # slot dim
                seen[name] += 1
            return leaf
        jax.tree_util.tree_map_with_path(visit, eng.caches)
        assert seen["pk"] > 0 and seen["table"] > 0, seen
        print("SHARDED-PAGED-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = f"{root}/src"
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "SHARDED-PAGED-OK" in out.stdout


def test_paged_dense_needs_page_multiple():
    """Dense layers keep max_len rows unrounded; a max_len that PAGE_SIZE
    does not divide cannot page without changing the view width (which
    would break bitwise identity) — it must refuse loudly."""
    cfg = get_smoke_config("llama3p2_1b")
    with pytest.raises(ValueError):
        Mod.paged_layout(cfg, 250)
