"""Context-parallel window attention: the paper's FIFO locality across
devices. A sequence sharded over N devices exchanges only a w-token halo
(jax.lax.ppermute) per attention call — wire bytes independent of L —
instead of the O(L) kv all-gather dense attention would force.

Runs on CPU with 4 forced host devices (re-execs itself to set the flag
before jax initializes).

    PYTHONPATH=src python examples/context_parallel.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ or "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.environ["TF_CPP_MIN_LOG_LEVEL"] = "2"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.types import AttentionSpec                    # noqa: E402
from repro.distributed import context_parallel as CP          # noqa: E402
from repro.kernels import ref as R                            # noqa: E402


def main():
    assert len(jax.devices()) == 4
    mesh = jax.make_mesh((4,), ("seq",))
    spec = AttentionSpec(kind="swat", window=256, num_global=16, causal=True)

    B, H, L, D = 1, 4, 4096, 64
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, L, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, H, L, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, H, L, D), jnp.float32) * 0.3

    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        out = CP.swat_attention_context_parallel(
            q, k, v, spec, mesh=mesh, axis="seq")
    ref = R.attention_ref(q, k, v, spec)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    print(f"CP(4 shards) vs O(N^2) oracle: max err {err:.2e}")
    assert err < 1e-3

    # the headline scaling: halo wire bytes don't grow with L
    print(f"{'L':>10} {'halo B/dev':>12} {'all-gather B/dev':>17} {'x':>7}")
    for L_ in (8192, 65536, 524288):
        halo = CP.cp_wire_bytes_per_device(L_, 16, 512, H, D, batch=B)
        ag = 2 * (L_ - L_ // 16) * H * D * 2 * B
        print(f"{L_:>10} {halo:>12,} {ag:>17,} {ag / halo:>6.0f}x")


if __name__ == "__main__":
    main()
