"""End-to-end driver: train a ~100M-param Longformer for a few hundred steps
on the synthetic LM stream, with checkpointing, auto-resume, straggler
logging, and a dense-attention control at matched size (the paper's
accuracy-parity story, Table 3, transplanted to an offline-runnable task).

    PYTHONPATH=src python examples/train_longformer.py --steps 300
    PYTHONPATH=src python examples/train_longformer.py --steps 300 --dense
    # kill it mid-run and re-run: it resumes from the last checkpoint
"""
import argparse

import jax

from repro.core.types import AttentionSpec, ModelConfig
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.runtime.trainer import TrainConfig, Trainer


def build_cfg(dense: bool) -> ModelConfig:
    # ~100M params: 12L x 768 with a 50k vocab
    attn = (AttentionSpec(kind="dense", causal=True) if dense else
            AttentionSpec(kind="swat", window=128, num_global=4, causal=True))
    return ModelConfig(
        name="longformer-100m" + ("-dense" if dense else ""),
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=50265, attention=attn, tie_embeddings=True,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--dense", action="store_true",
                    help="dense-attention control run")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_longformer")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (restart drill)")
    args = ap.parse_args()

    cfg = build_cfg(args.dense)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.core.model", fromlist=["m"])
                       .init_model(jax.random.PRNGKey(0), cfg))))
    print(f"[example] {cfg.name}: {n_params/1e6:.1f}M params")

    trainer = Trainer(
        cfg,
        adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        TrainConfig(total_steps=args.steps, ckpt_every=50,
                    ckpt_dir=args.ckpt_dir + ("-dense" if args.dense else ""),
                    log_every=10, fail_at_step=args.fail_at,
                    metrics_path="/tmp/longformer_metrics.jsonl"),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch))
    out = trainer.train()
    losses = [h["loss"] for h in out["history"]]
    print(f"[example] first-10 loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 loss {sum(losses[-10:])/10:.3f}")
    if out["stragglers"]:
        print(f"[example] straggler steps flagged: {out['stragglers'][:5]}")


if __name__ == "__main__":
    main()
