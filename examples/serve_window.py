"""Batched serving with ring KV caches: continuous batching over more
requests than slots; memory report shows the O(window) cache (paper Fig. 3).

    PYTHONPATH=src python examples/serve_window.py
"""
import time

import jax
import numpy as np

from repro.core.types import AttentionSpec, ModelConfig
from repro.core import model as Mod
from repro.serving.engine import Request, ServingEngine, ring_cache_bytes


def main():
    cfg = ModelConfig(
        name="serve-demo", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, d_ff=1024, vocab_size=32000,
        attention=AttentionSpec(kind="swat", window=256, num_global=4,
                                causal=True),
        dtype="float32")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.RandomState(0)
    requests = [
        Request(rid=i,
                prompt=rng.randint(0, cfg.vocab_size, (64,)).astype(np.int32),
                max_new_tokens=16)
        for i in range(6)
    ]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=2048)
    t0 = time.time()
    results = engine.run(requests)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok/dt:.1f} tok/s on CPU)")
    for r in results[:3]:
        print(f"  rid={r.rid}: {r.tokens[:8]}...")

    swat_bytes = ring_cache_bytes(cfg, 2, 65536)
    dense_cfg = ModelConfig(**{**cfg.__dict__,
                               "attention": AttentionSpec(kind="dense",
                                                          causal=True)})
    dense_bytes = ring_cache_bytes(dense_cfg, 2, 65536)
    print(f"[serve] decode-cache @64k context: ring={swat_bytes/1e6:.1f}MB "
          f"vs dense={dense_bytes/1e6:.1f}MB "
          f"({dense_bytes/swat_bytes:.0f}x saving — paper Fig. 3)")


if __name__ == "__main__":
    main()
