"""Pipeline-parallel training: GPipe microbatches over the super-block seam.

A 2-stage pipeline on a (pipe=2, data=2) CPU mesh trains a small window-
attention LM; the script verifies the pipelined loss matches the single-pass
loss before training, then runs real PP steps.

    PYTHONPATH=src python examples/pipeline_train.py
"""
import os
import sys

if "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.environ["TF_CPP_MIN_LOG_LEVEL"] = "2"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time                                                   # noqa: E402

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.types import AttentionSpec, ModelConfig      # noqa: E402
from repro.core import model as Mod                           # noqa: E402
from repro.distributed import pipeline as PP                  # noqa: E402
from repro.launch import mesh as mesh_lib                     # noqa: E402
from repro.optim import adamw                                 # noqa: E402


def main():
    cfg = ModelConfig(
        name="pp-demo", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=1000,
        attention=AttentionSpec(kind="swat", window=64, causal=True),
        dtype="float32")
    mesh = mesh_lib.make_debug_pp_mesh(n_pipe=2, n_data=2)
    pcfg = PP.PipelineConfig(num_stages=2, num_microbatches=4)
    print(f"stages=2 microbatches=4 "
          f"bubble={PP.bubble_fraction(pcfg):.2f}")

    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 1000, (8, 128)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]

    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        loss_fn = PP.make_pipeline_loss(cfg, pcfg, mesh)
        l_pp, _ = jax.jit(loss_fn)(params, batch)
    l_ref, _ = Mod.loss_fn(params, cfg, batch, remat=False)
    print(f"PP loss {float(l_pp):.4f} == single-pass {float(l_ref):.4f}")
    assert abs(float(l_pp) - float(l_ref)) < 1e-2

    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=5)
    opt = adamw.init_opt_state(params)
    with mesh:  # legacy ambient-mesh context (jax.set_mesh needs newer jax)
        step = jax.jit(PP.make_pp_train_step(cfg, opt_cfg, pcfg, mesh))
        for i in range(20):
            t0 = time.time()
            params, opt, m = step(params, opt, batch)
            if i % 5 == 0:
                print(f"step {i:>3} loss={float(m['loss']):.4f} "
                      f"({(time.time() - t0) * 1e3:.0f} ms)")
    print("pipeline training ran; loss decreased:",
          float(m["loss"]) < float(l_pp))


if __name__ == "__main__":
    main()
