"""Quickstart: the SWAT attention op + a tiny Longformer in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AttentionSpec, ModelConfig
from repro.core import model as Mod
from repro.kernels.ops import swat_attention

# --- 1. the paper's op: fused exact-band window attention ------------------
rng = np.random.RandomState(0)
B, H, L, D = 2, 4, 1024, 64
q = jnp.asarray(rng.randn(B, H, L, D), jnp.float32)
k = jnp.asarray(rng.randn(B, H, L, D), jnp.float32)
v = jnp.asarray(rng.randn(B, H, L, D), jnp.float32)

spec = AttentionSpec(kind="swat", window=128, num_global=16, causal=False)
out = swat_attention(q, k, v, spec, impl="pallas")     # Pallas kernel
out_xla = swat_attention(q, k, v, spec, impl="xla")    # SPMD-friendly twin
print("swat attention:", out.shape,
      "pallas-vs-xla max err:",
      float(jnp.max(jnp.abs(out - out_xla))))

# --- 2. a tiny Longformer LM, one training step -----------------------------
cfg = ModelConfig(
    name="tiny-longformer", num_layers=4, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=1000,
    attention=AttentionSpec(kind="swat", window=64, num_global=4,
                            causal=True),
    dtype="float32")
params = Mod.init_model(jax.random.PRNGKey(0), cfg)
tokens = jnp.asarray(rng.randint(0, 1000, (2, 256)), jnp.int32)
(loss, metrics), grads = jax.value_and_grad(Mod.loss_fn, has_aux=True)(
    params, cfg, {"tokens": tokens, "labels": tokens})
print(f"tiny longformer loss={float(loss):.3f} "
      f"params={sum(x.size for x in jax.tree.leaves(params)):,}")

# --- 3. decode with the ring KV cache (the paper's FIFO) --------------------
logits, caches = Mod.prefill(params, cfg, {"tokens": tokens[:, :128]},
                             max_len=512)
tok = jnp.argmax(logits[:, 0], -1)[:, None]
for step in range(8):
    logits, caches = Mod.decode_step(params, cfg, {"tokens": tok}, caches)
    tok = jnp.argmax(logits[:, 0], -1)[:, None]
ring = caches["l0"]["k"].shape  # (super_blocks, B, Hkv, window+1+g, D)
print("ring cache per layer:", ring, "- O(window), not O(context)")
