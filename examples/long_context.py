"""Long-context decode: a small SWAT model decodes with a 100k-token-deep
context on CPU in O(window) memory — the workload that motivates the paper
(and the long_500k dry-run cell at production scale).

    PYTHONPATH=src python examples/long_context.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AttentionSpec, ModelConfig
from repro.core import model as Mod
from repro.serving.engine import ring_cache_bytes


def main():
    cfg = ModelConfig(
        name="long-ctx-demo", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=1000,
        attention=AttentionSpec(kind="swat", window=512, num_global=8,
                                causal=True),
        dtype="float32")
    params = Mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    # prefill a 4k prompt, then decode far past it — the ring keeps memory flat
    prompt = jnp.asarray(rng.randint(0, 1000, (1, 4096)), jnp.int32)
    logits, caches = Mod.prefill(params, cfg, {"tokens": prompt},
                                 max_len=131072)
    decode = jax.jit(lambda p, c, b: Mod.decode_step(p, cfg, b, c))
    tok = jnp.argmax(logits[:, 0], -1)[:, None]
    t0, n = time.time(), 256
    for i in range(n):
        logits, caches = decode(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
    dt = time.time() - t0
    step = int(caches["l0"]["step"][0, 0])   # per-slot steps: (blocks, batch)
    cache_mb = ring_cache_bytes(cfg, 1, 131072) / 1e6
    print(f"[long-ctx] decoded {n} tokens at context depth {step} "
          f"({n/dt:.1f} tok/s CPU)")
    print(f"[long-ctx] decode cache: {cache_mb:.2f}MB flat "
          f"(window=512) — dense at 131k would be "
          f"{ring_cache_bytes(ModelConfig(**{**cfg.__dict__, 'attention': AttentionSpec(kind='dense', causal=True)}), 1, 131072)/1e6:.0f}MB")
    assert bool(jnp.isfinite(logits).all())


if __name__ == "__main__":
    main()
